"""Dry-run comm sweep: the scenario registry's wire configs through the
512-device cost model (ROADMAP open item).

For each comm-flavored scenario, lower + compile the mesh train step on
the 512-placeholder-device multi-pod mesh (launch/dryrun.py) with that
scenario's CommConfig threaded through `build_step`, and report the
per-scenario collective-bytes delta against the ideal dense wire. This
prices a comm regime *before* burning real pod time: a compressor that
saves uplink in the fleet simulation but inflates on-mesh collectives
shows up here first.

One table, saved to artifacts/dryrun/comm_scenarios[_reduced].json.

  PYTHONPATH=src python -m benchmarks.comm_dryrun_sweep \\
      [--arch smollm-360m] [--shape train_4k] [--scenarios a,b,...]
      [--reduced]

Full-size archs need a large-memory host (the 512-way SPMD compile of
the full smollm-360m train step exceeds a 128 GB box); `--reduced`
compiles the reduced arch variant, which preserves the *relative*
collective-bytes deltas between comm configs.
"""
from __future__ import annotations

import argparse
import json

# MUST be first repro import: dryrun pins XLA's host platform device
# count to 512 before jax initializes.
from repro.launch import dryrun  # noqa: I001

from benchmarks.common import print_table
from repro.experiments import get_scenario, list_scenarios

# registry scenarios whose comm configs are worth pricing on the mesh
# (paper/fig3-noniid1 carries the default wire = the dense baseline)
DEFAULT_SCENARIOS = [
    "paper/fig3-noniid1",
    "low-bandwidth-int4",
    "low-bandwidth-topk",
    "lossy-uplink-erasure",
    "byzantine-median",
    "adaptive-tiers",
    "rayleigh-uplink",
    "snr-tiered-bits",
]


def run(arch: str = "smollm-360m", shape: str = "train_4k",
        scenarios: list[str] | None = None, save_hlo: bool = False,
        reduced: bool = False) -> dict:
    scenarios = scenarios or DEFAULT_SCENARIOS
    real_get_arch = dryrun.get_arch
    if reduced:
        # compile the reduced arch variant: relative collective-bytes
        # deltas between comm configs survive the shrink, and the full
        # 512-device multi-pod SPMD program stays the thing being priced
        # (full-size compiles need ~all of a 128 GB host)
        dryrun.get_arch = lambda name: real_get_arch(name).reduced()
    rows, recs = [], {}
    baseline_bytes = None
    try:
        for name in scenarios:
            comm = get_scenario(name).comm
            tag = "__comm-" + name.replace("/", "-") + (
                "-reduced" if reduced else "")
            rec = dryrun.run_one(arch, shape, "multi", algorithm="mdsl",
                                 save_hlo=save_hlo, tag=tag, comm=comm)
            recs[name] = rec
            if not rec.get("ok"):
                rows.append([name, "FAIL", rec.get("error", "?")[:40],
                             "", ""])
                continue
            coll = rec["collectives"]["total_bytes"]
            # deltas are only meaningful against the named baseline
            # scenario (scenarios[0]); if that one failed, report n/a
            # rather than silently re-baselining on a later config
            if name == scenarios[0]:
                baseline_bytes = coll
            delta = (f"{(coll - baseline_bytes) / baseline_bytes:+.1%}"
                     if baseline_bytes else "n/a")
            rows.append([
                name,
                f"{coll / 2**30:.3f}GiB",
                delta,
                f"{rec['flops_per_device'] / 1e12:.2f}T",
                rec["roofline"]["dominant"]])
            print(f"  {name}: collectives {coll / 2**30:.3f} GiB "
                  f"({delta} vs {scenarios[0]})", flush=True)
    finally:
        dryrun.get_arch = real_get_arch

    print_table(
        ["scenario", "collective bytes/dev", f"delta vs {scenarios[0]}",
         "flops/dev", "bound"],
        rows,
        f"512-device dry-run comm sweep — {arch} / {shape} (multi-pod)")

    out = {"arch": arch, "shape": shape, "mesh": "multi",
           "reduced": reduced, "baseline_scenario": scenarios[0],
           "baseline_ok": baseline_bytes is not None,
           "scenarios": {n: {k: r[k] for k in
                             ("ok", "comm", "collectives",
                              "flops_per_device", "roofline")
                             if k in r} | (
                             {"error": r["error"]} if "error" in r else {})
                         for n, r in recs.items()}}
    dryrun.ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)
    path = dryrun.ARTIFACT_DIR / ("comm_scenarios_reduced.json" if reduced
                                  else "comm_scenarios.json")
    path.write_text(json.dumps(out, indent=1))
    print(f"wrote {path}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--shape", default="train_4k",
                    choices=["train_4k", "prefill_32k", "decode_32k"])
    ap.add_argument("--scenarios", default=None,
                    help=f"comma list (default {','.join(DEFAULT_SCENARIOS)};"
                         f" registry: {','.join(list_scenarios())})")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced arch variant (fits small hosts; "
                         "relative deltas preserved)")
    args = ap.parse_args()
    run(arch=args.arch, shape=args.shape,
        scenarios=args.scenarios.split(",") if args.scenarios else None,
        save_hlo=args.save_hlo, reduced=args.reduced)


if __name__ == "__main__":
    main()
