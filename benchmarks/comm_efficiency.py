"""Paper §IV-C: communication efficiency, in bytes on the wire.

FedAvg uploads C dense models per round; M-DSL uploads only the Eq.-6
selected subset — and with `repro.comm` the payload itself shrinks
(top-k / int8 / int4 with error feedback). This benchmark sweeps
algorithms x compressors and reports accuracy-vs-bytes trade-off
curves: total uplink bytes, rounds-to-target-accuracy, and the byte
cost of reaching the target.
"""
from __future__ import annotations

from benchmarks.common import print_table, save_record
from repro.comm import CommConfig
from repro.launch.train import run_paper_experiment

SWEEP = [
    ("identity", CommConfig()),
    ("topk5%", CommConfig(compressor="topk", topk_ratio=0.05)),
    ("int8", CommConfig(compressor="int8")),
    ("int4", CommConfig(compressor="int4")),
]


def rounds_to(acc_curve: list[float], target: float) -> int | None:
    for i, a in enumerate(acc_curve):
        if a >= target:
            return i + 1
    return None


def bytes_to(acc_curve: list[float], bytes_up: list[float],
             target: float) -> float | None:
    total = 0.0
    for a, b in zip(acc_curve, bytes_up):
        total += b
        if a >= target:
            return total
    return None


def run(quick: bool = True, dataset: str = "mnist_like", seed: int = 0,
        algorithms: tuple[str, ...] = ("fedavg", "mdsl")) -> dict:
    rounds = 8 if quick else 20
    width = 2 if quick else 8
    workers = 10 if quick else 50
    recs = {}
    for algo in algorithms:
        for cname, comm in SWEEP:
            recs[(algo, cname)] = run_paper_experiment(
                algorithm=algo, case="noniid1", dataset=dataset,
                rounds=rounds, num_workers=workers, width_mult=width,
                local_epochs=2, n_local=256 if quick else 512,
                lr=0.05 if quick else 0.01, velocity_clip=0.1, seed=seed,
                comm=comm, verbose=False)

    # baselines: dense FedAvg when it ran, else the first algorithm's
    # identity run (run() accepts any algorithm subset)
    ref_algo = "fedavg" if "fedavg" in algorithms else algorithms[0]
    n = recs[(algorithms[0], "identity")]["n_params"]
    C = workers
    target = 0.9 * max(recs[(ref_algo, "identity")]["best_acc"], 1e-9)

    rows = []
    for (algo, cname), r in recs.items():
        total = r["total_bytes_up"]
        rows.append([
            algo, cname, f"{r['final_acc']:.3f}",
            f"{sum(r['selected']) / rounds:.1f}/{C}",
            f"{r['compression_ratio']:.1f}x",
            f"{total / 2**20:.2f}MiB",
            rounds_to(r["acc"], target) or f">{rounds}",
            (lambda b: f"{b / 2**20:.2f}MiB" if b else "-")(
                bytes_to(r["acc"], r["bytes_up"], target))])
    print_table(
        ["algorithm", "compressor", "final_acc", "uploads/round",
         "ratio", "total up", f"rounds to {target:.2f}",
         f"bytes to {target:.2f}"],
        rows, "§IV-C — communication efficiency (non-iid I), bytes on wire")

    ref_total = recs[(ref_algo, "identity")]["total_bytes_up"]
    best_key = min(
        ((k, r) for k, r in recs.items()
         if r["final_acc"] >= target),
        key=lambda kr: kr[1]["total_bytes_up"], default=(None, None))[0]
    rec = {"n_params": n, "C": C, "rounds": rounds, "target_acc": target,
           "ref_algorithm": ref_algo, "ref_dense_bytes": ref_total}
    if "fedavg" in algorithms and "mdsl" in algorithms:
        fed_total = recs[("fedavg", "identity")]["total_bytes_up"]
        mdsl_total = recs[("mdsl", "identity")]["total_bytes_up"]
        saving_sel = 1.0 - mdsl_total / fed_total
        print(f"M-DSL selection-only saving vs FedAvg: "
              f"{100 * saving_sel:.1f}%")
        rec.update({
            "fedavg_dense_bytes": fed_total,
            "mdsl_dense_bytes": mdsl_total,
            "selection_saving_frac": saving_sel,
            # legacy fields (parameter counts) kept for older consumers
            "fedavg_total_uploads": n * C * rounds,
            "mdsl_total_uploads": recs[("mdsl", "identity")][
                "total_uploaded_params"],
            "saving_frac": saving_sel,
            "mdsl_selected_trace": recs[("mdsl", "identity")]["selected"],
            "fedavg_acc": recs[("fedavg", "identity")]["acc"],
            "mdsl_acc": recs[("mdsl", "identity")]["acc"]})
    if best_key:
        best = recs[best_key]
        print(f"cheapest config reaching {target:.2f}: "
              f"{best_key[0]}+{best_key[1]} at "
              f"{best['total_bytes_up'] / 2**20:.2f}MiB "
              f"({ref_total / max(best['total_bytes_up'], 1):.1f}x less "
              f"than dense {ref_algo})")

    rec.update({"sweep": {f"{a}+{c}": {
               "final_acc": r["final_acc"],
               "acc": r["acc"],
               "total_bytes_up": r["total_bytes_up"],
               "bytes_up": r["bytes_up"],
               "compression_ratio": r["compression_ratio"],
               "selected": r["selected"],
               "delivered": r["delivered"],
           } for (a, c), r in recs.items()}})
    save_record("comm_efficiency", rec)
    return rec


if __name__ == "__main__":
    run()
