"""Paper §IV-C: communication efficiency.

FedAvg uploads n*C parameters per round; M-DSL uploads n*sum_i s_{i,t}.
The paper claims a small subset of workers represents the fleet after the
early training stage, and M-DSL converges in fewer rounds. This benchmark
measures uploaded parameters per round and rounds-to-target-accuracy.
"""
from __future__ import annotations

from benchmarks.common import print_table, save_record
from repro.launch.train import run_paper_experiment


def rounds_to(acc_curve: list[float], target: float) -> int | None:
    for i, a in enumerate(acc_curve):
        if a >= target:
            return i + 1
    return None


def run(quick: bool = True, dataset: str = "mnist_like", seed: int = 0
        ) -> dict:
    rounds = 8 if quick else 20
    width = 2 if quick else 8
    workers = 10 if quick else 50
    recs = {}
    for algo in ["fedavg", "mdsl"]:
        recs[algo] = run_paper_experiment(
            algorithm=algo, case="noniid1", dataset=dataset, rounds=rounds,
            num_workers=workers, width_mult=width, local_epochs=2,
            n_local=256 if quick else 512, lr=0.05 if quick else 0.01,
            velocity_clip=0.1, seed=seed, verbose=False)

    n = recs["mdsl"]["n_params"]
    C = workers
    fed_total = n * C * rounds
    mdsl_total = recs["mdsl"]["total_uploaded_params"]
    target = 0.9 * max(recs["fedavg"]["best_acc"], 1e-9)

    rows = []
    for algo in ["fedavg", "mdsl"]:
        r = recs[algo]
        total = (fed_total if algo == "fedavg"
                 else r["total_uploaded_params"])
        rows.append([
            algo, f"{r['final_acc']:.3f}",
            f"{sum(r['selected']) / rounds:.1f}/{C}",
            f"{total / 1e6:.1f}M",
            rounds_to(r["acc"], target) or f">{rounds}"])
    print_table(
        ["algorithm", "final_acc", "mean uploads/round", "total params up",
         f"rounds to {target:.2f}"],
        rows, "§IV-C — communication efficiency (non-iid I)")
    saving = 1.0 - mdsl_total / fed_total
    print(f"M-DSL upload saving vs FedAvg: {100 * saving:.1f}%")

    rec = {"n_params": n, "C": C, "rounds": rounds,
           "fedavg_total_uploads": fed_total,
           "mdsl_total_uploads": mdsl_total, "saving_frac": saving,
           "mdsl_selected_trace": recs["mdsl"]["selected"],
           "fedavg_acc": recs["fedavg"]["acc"],
           "mdsl_acc": recs["mdsl"]["acc"]}
    save_record("comm_efficiency", rec)
    return rec


if __name__ == "__main__":
    run()
