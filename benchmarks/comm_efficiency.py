"""Paper §IV-C: communication efficiency, in bytes on the wire.

FedAvg uploads C dense models per round; M-DSL uploads only the Eq.-6
selected subset — and with `repro.comm` the payload itself shrinks
(top-k / int8 / int4 with error feedback), the downlink broadcast can
be quantized with PS-side error feedback, and the PS can assign wire
tiers per worker from the Eq.-5 rank. This benchmark is a thin client
of the scenario registry: the base spec is `paper/fig3-noniid1`, and
every swept axis (algorithm, compressor, aggregator, attack) is a
dotted-path override. It reports accuracy-vs-total-bytes (up + down)
trade-off curves, plus a Byzantine sweep showing where median /
trimmed-mean aggregation retains accuracy while the masked mean
degrades.

`--json` additionally runs the straggler sweep — accuracy vs
`round_deadline_s` x staleness-gamma, FedAvg vs M-DSL selection, plus a
quorum-gated cell — and writes BENCH_stragglers.json at the repo root
(the CI straggler-smoke job asserts its shape): the graceful-degradation
claim of the deadline engine (comm.straggler), with numbers.

Usage:
  python -m benchmarks.comm_efficiency --aggregator median \\
      --downlink-compressor int8
  python -m benchmarks.comm_efficiency --full --byzantine 3
  python -m benchmarks.comm_efficiency --quick --json
"""
from __future__ import annotations

import argparse
import json

from benchmarks.common import ROOT, print_table, save_record
from repro.comm import AGGREGATORS, COMPRESSORS
from repro.experiments import ExperimentSpec, get_scenario, override
from repro.experiments import run as run_spec
from repro.obs.events import NULL, Emitter, new_run_id
from repro.obs.sinks import JsonlSink, default_obs_dir

# benchmark-level obs stream (--obs): one SweepEvent per swept cell with
# the accuracy/bytes/energy cumulants, so the §IV-C tables — including
# accuracy-per-joule — are derivable from event streams alone. Each cell
# run additionally writes its own per-round stream.
_EM = NULL


def _obs_enable(tag: str) -> None:
    global _EM
    rid = new_run_id(f"bench__comm_efficiency__{tag}")
    _EM = Emitter(rid, JsonlSink(default_obs_dir() / f"{rid}.jsonl"))

SWEEP = [
    ("identity", ("comm.compressor=identity",)),
    ("topk5%", ("comm.compressor=topk", "comm.topk_ratio=0.05")),
    ("int8", ("comm.compressor=int8",)),
    ("int4", ("comm.compressor=int4",)),
]

# accuracy-vs-energy axis (comm.phy): Rayleigh uplinks at 10 dB mean
# SNR, with/without SNR outage, and the channel-aware N=3 bit tiers
# ranked by instantaneous SNR (good channels earn more bits) — vs the
# same tiers ranked by the Eq.-5 score, blind to the channel.
_RAYLEIGH = ("comm.channel=awgn", "comm.snr_db=10.0",
             "comm.fading=rayleigh", "comm.doppler_rho=0.9")
PHY_SWEEP = [
    ("ideal", ()),
    ("rayleigh", _RAYLEIGH),
    ("rayleigh+outage", _RAYLEIGH + ("comm.channel=composite",
                                     "comm.drop_prob=0.05",
                                     "comm.outage_snr_db=0.0")),
    ("snr-tiers(3)", _RAYLEIGH + ("comm.adaptive_bits=true",
                                  "comm.num_tiers=3",
                                  "comm.tier_rank=snr")),
    ("score-tiers(3)", _RAYLEIGH + ("comm.adaptive_bits=true",
                                    "comm.num_tiers=3",
                                    "comm.tier_rank=score")),
]

QUICK = ("run.rounds=8", "model.width_mult=2", "data.num_workers=10",
         "data.n_local=256", "algo.hp.learning_rate=0.05")

# straggler grid (comm.straggler): deadlines bracket the quick model's
# ~24 ms airtime at the Rayleigh 10 dB budget (loose / binding / tight),
# gammas span drain-at-full-weight vs 1/(1+age) FedBuff discounting
STRAGGLER_DEADLINES = (0.05, 0.025, 0.015)
STRAGGLER_GAMMAS = (0.0, 1.0)
STRAGGLER_JSON = ROOT / "BENCH_stragglers.json"


def rounds_to(acc_curve: list[float], target: float) -> int | None:
    for i, a in enumerate(acc_curve):
        if a >= target:
            return i + 1
    return None


def bytes_to(acc_curve: list[float], bytes_total: list[float],
             target: float) -> float | None:
    total = 0.0
    for a, b in zip(acc_curve, bytes_total):
        total += b
        if a >= target:
            return total
    return None


def base_spec(*, quick: bool, dataset: str, seed: int, aggregator: str,
              downlink_compressor: str, adaptive_bits: bool
              ) -> ExperimentSpec:
    spec = get_scenario("paper/fig3-noniid1")
    if quick:
        spec = override(spec, *QUICK)
    return override(spec, "algo.local_epochs=2",
                    f"data.dataset={dataset}", f"run.seed={seed}",
                    f"comm.aggregator={aggregator}",
                    f"comm.downlink_compressor={downlink_compressor}",
                    f"comm.adaptive_bits={adaptive_bits}").validate()


def _run_one(spec: ExperimentSpec, *overrides: str,
             cell: str = "cell") -> dict:
    sp = override(spec, *overrides) if overrides else spec
    if _EM.active:
        sp = override(sp, "run.obs.enabled=true")
    res = run_spec(sp, verbose=False)
    r = res.record
    r["total_bytes"] = r["total_bytes_up"] + r["total_bytes_down"]
    r["bytes_total"] = [u + d for u, d in zip(r["bytes_up"],
                                              r["bytes_down"])]
    _EM.sweep_cell(cell, seed=sp.run.seed, final=r["final_acc"],
                   events=res.events_path,
                   metrics={"final_acc": r["final_acc"],
                            "best_acc": r["best_acc"],
                            "total_bytes": r["total_bytes"],
                            "total_bytes_up": r["total_bytes_up"],
                            "total_bytes_down": r["total_bytes_down"],
                            "total_airtime_s": r["total_airtime_s"],
                            "total_energy_j": r["total_energy_j"]})
    return r


def byzantine_sweep(spec: ExperimentSpec, byzantine: int) -> dict:
    """Robust-aggregation comparison under attack: FedAvg (every worker
    aggregated — the worst-case exposure) with `byzantine` adversarial
    workers, across Eq.-7 aggregators. Selection-based M-DSL is the
    paper's defense; median / trimmed mean are the aggregation-level
    defense that also protects the no-selection baseline."""
    workers = spec.data.num_workers
    # a trimmed mean only tolerates what it trims: cut at least the
    # attacked fraction from each end
    trim = min(max(spec.comm.trim_ratio, byzantine / workers), 0.45)
    attack = override(spec, f"comm.byzantine={byzantine}",
                      "comm.byzantine_mode=gaussian",
                      "comm.byzantine_scale=25.0",
                      f"comm.trim_ratio={trim}")
    out = {"byzantine": byzantine, "attack": attack.comm._asdict(),
           "runs": {}}
    rows = []
    for agg in AGGREGATORS:
        r = _run_one(attack, "algo.algorithm=fedavg",
                     f"comm.aggregator={agg}",
                     cell=f"byz{byzantine}/fedavg+{agg}")
        out["runs"][agg] = {"final_acc": r["final_acc"],
                            "best_acc": r["best_acc"], "acc": r["acc"],
                            "total_bytes": r["total_bytes"]}
        rows.append([f"fedavg+{agg}", f"{r['final_acc']:.3f}",
                     f"{r['best_acc']:.3f}",
                     f"{r['total_bytes'] / 2**20:.2f}MiB"])
    # the paper's selection defense, for reference: plain-mean Eq. 7 so
    # the row isolates selection (not selection + robust aggregation)
    r = _run_one(attack, "algo.algorithm=mdsl", "comm.aggregator=mean",
                 cell=f"byz{byzantine}/mdsl+mean")
    out["runs"]["mdsl_selection"] = {"final_acc": r["final_acc"],
                                     "best_acc": r["best_acc"],
                                     "acc": r["acc"],
                                     "total_bytes": r["total_bytes"]}
    rows.append(["mdsl+mean(sel.)", f"{r['final_acc']:.3f}",
                 f"{r['best_acc']:.3f}",
                 f"{r['total_bytes'] / 2**20:.2f}MiB"])
    print_table(["defense", "final_acc", "best_acc", "total bytes"], rows,
                f"Byzantine sweep ({byzantine} gaussian attackers)")
    return out


def phy_sweep(spec: ExperimentSpec) -> dict:
    """Accuracy-vs-energy over the physical-layer regimes: every run
    reports its SNR->rate airtime and transmit energy (comm.phy), so
    the table prices accuracy per joule — including the channel-aware
    N=3 SNR-ranked bit tiers against their channel-blind score-ranked
    twin."""
    out = {}
    rows = []
    for name, ovr in PHY_SWEEP:
        r = _run_one(spec, "algo.algorithm=mdsl", *ovr,
                     cell=f"phy/{name}")
        out[name] = {
            "final_acc": r["final_acc"], "best_acc": r["best_acc"],
            "acc": r["acc"], "total_bytes": r["total_bytes"],
            "total_airtime_s": r["total_airtime_s"],
            "total_energy_j": r["total_energy_j"],
            "mean_snr_db": r["mean_snr_db"], "delivered": r["delivered"]}
        eff = r["final_acc"] / max(r["total_energy_j"], 1e-12)
        rows.append([name, f"{r['final_acc']:.3f}",
                     f"{r['total_bytes'] / 2**20:.2f}MiB",
                     f"{r['total_airtime_s']:.3f}s",
                     f"{r['total_energy_j']:.3f}J",
                     f"{eff:.2f}"])
    print_table(["phy regime", "final_acc", "total bytes", "airtime",
                 "energy", "acc/J"], rows,
                "accuracy vs energy (Rayleigh uplink, SNR->rate airtime)")
    return out


def straggler_sweep(spec: ExperimentSpec,
                    algorithms: tuple[str, ...] = ("fedavg", "mdsl")
                    ) -> dict:
    """Accuracy vs round deadline x staleness-gamma on a heterogeneous
    Rayleigh uplink (pathloss spread + fading make the slow tail late),
    FedAvg vs M-DSL selection, plus one quorum-gated cell. A tighter
    deadline parks more uploads; gamma prices how much a drained stale
    delta still counts — the table shows where buffering holds accuracy
    against simply losing the late uploads."""
    base = override(spec, *_RAYLEIGH, "comm.pathloss_spread_db=6.0")
    out = {"deadlines_s": list(STRAGGLER_DEADLINES),
           "gammas": list(STRAGGLER_GAMMAS), "runs": {}}
    rows = []

    def cell(algo: str, name: str, *ovr: str) -> None:
        r = _run_one(base, f"algo.algorithm={algo}", *ovr,
                     cell=f"straggler/{name}")
        late = sum(r.get("late", []))
        drained = sum(r.get("drained", []))
        holds = sum(r.get("held", []))
        out["runs"][name] = {
            "final_acc": r["final_acc"], "best_acc": r["best_acc"],
            "acc": r["acc"], "total_bytes": r["total_bytes"],
            "total_airtime_s": r["total_airtime_s"],
            "late": r.get("late"), "drained": r.get("drained"),
            "buffered": r.get("buffered"), "held": r.get("held")}
        rows.append([name, f"{r['final_acc']:.3f}", f"{r['best_acc']:.3f}",
                     int(late), int(drained), int(holds),
                     f"{r['total_bytes'] / 2**20:.2f}MiB"])

    for algo in algorithms:
        cell(algo, f"{algo}/no-deadline")
        for d in STRAGGLER_DEADLINES:
            for g in STRAGGLER_GAMMAS:
                cell(algo, f"{algo}/ddl{d:g}/g{g:g}",
                     f"comm.round_deadline_s={d}",
                     f"comm.staleness_gamma={g}")
    # graceful degradation: the PS holds w_t when a thin round cannot
    # reach quorum instead of averaging whatever trickled in
    tight = STRAGGLER_DEADLINES[-1]
    cell("mdsl", f"mdsl/ddl{tight:g}/g1/quorum4",
         f"comm.round_deadline_s={tight}", "comm.staleness_gamma=1.0",
         "comm.quorum=4")
    print_table(["cell", "final_acc", "best_acc", "late", "drained",
                 "holds", "total bytes"], rows,
                "straggler sweep — accuracy vs deadline x staleness-γ "
                "(Rayleigh 10 dB, 6 dB pathloss spread)")
    return out


def run(quick: bool = True, dataset: str = "mnist_like", seed: int = 0,
        algorithms: tuple[str, ...] = ("fedavg", "mdsl"),
        aggregator: str = "mean", downlink_compressor: str = "identity",
        adaptive_bits: bool = False, byzantine: int = 2,
        rounds_override: int | None = None, phy: bool = True,
        obs: bool = False, stragglers: bool = False) -> dict:
    if obs:
        _obs_enable(f"{dataset}__s{seed}")
    base = base_spec(quick=quick, dataset=dataset, seed=seed,
                     aggregator=aggregator,
                     downlink_compressor=downlink_compressor,
                     adaptive_bits=adaptive_bits)
    if rounds_override is not None:
        base = override(base, f"run.rounds={rounds_override}")
    rounds, workers = base.run.rounds, base.data.num_workers
    recs = {}
    for algo in algorithms:
        for cname, ovr in SWEEP:
            recs[(algo, cname)] = _run_one(base, f"algo.algorithm={algo}",
                                           *ovr, cell=f"{algo}+{cname}")

    # baselines: dense FedAvg when it ran, else the first algorithm's
    # identity run (run() accepts any algorithm subset)
    ref_algo = "fedavg" if "fedavg" in algorithms else algorithms[0]
    n = recs[(algorithms[0], "identity")]["n_params"]
    C = workers
    target = 0.9 * max(recs[(ref_algo, "identity")]["best_acc"], 1e-9)

    rows = []
    for (algo, cname), r in recs.items():
        rows.append([
            algo, cname, f"{r['final_acc']:.3f}",
            f"{sum(r['selected']) / rounds:.1f}/{C}",
            f"{r['compression_ratio']:.1f}x",
            f"{r['total_bytes_up'] / 2**20:.2f}MiB",
            f"{r['total_bytes_down'] / 2**20:.2f}MiB",
            rounds_to(r["acc"], target) or f">{rounds}",
            (lambda b: f"{b / 2**20:.2f}MiB" if b else "-")(
                bytes_to(r["acc"], r["bytes_total"], target))])
    print_table(
        ["algorithm", "compressor", "final_acc", "uploads/round",
         "ratio", "total up", "total down", f"rounds to {target:.2f}",
         f"bytes to {target:.2f}"],
        rows, "§IV-C — communication efficiency (non-iid I), "
              f"bytes on wire [agg={aggregator} "
              f"down={downlink_compressor}"
              f"{' adaptive' if adaptive_bits else ''}]")

    ref_total = recs[(ref_algo, "identity")]["total_bytes_up"]
    best_key = min(
        ((k, r) for k, r in recs.items()
         if r["final_acc"] >= target),
        key=lambda kr: kr[1]["total_bytes"], default=(None, None))[0]
    rec = {"n_params": n, "C": C, "rounds": rounds, "target_acc": target,
           "aggregator": aggregator,
           "downlink_compressor": downlink_compressor,
           "adaptive_bits": adaptive_bits,
           "ref_algorithm": ref_algo, "ref_dense_bytes": ref_total}
    if "fedavg" in algorithms and "mdsl" in algorithms:
        fed_total = recs[("fedavg", "identity")]["total_bytes_up"]
        mdsl_total = recs[("mdsl", "identity")]["total_bytes_up"]
        saving_sel = 1.0 - mdsl_total / fed_total
        print(f"M-DSL selection-only saving vs FedAvg: "
              f"{100 * saving_sel:.1f}%")
        rec.update({
            "fedavg_dense_bytes": fed_total,
            "mdsl_dense_bytes": mdsl_total,
            "selection_saving_frac": saving_sel,
            # legacy fields (parameter counts) kept for older consumers
            "fedavg_total_uploads": n * C * rounds,
            "mdsl_total_uploads": recs[("mdsl", "identity")][
                "total_uploaded_params"],
            "saving_frac": saving_sel,
            "mdsl_selected_trace": recs[("mdsl", "identity")]["selected"],
            "fedavg_acc": recs[("fedavg", "identity")]["acc"],
            "mdsl_acc": recs[("mdsl", "identity")]["acc"]})
    if best_key:
        best = recs[best_key]
        print(f"cheapest config reaching {target:.2f}: "
              f"{best_key[0]}+{best_key[1]} at "
              f"{best['total_bytes'] / 2**20:.2f}MiB up+down "
              f"({ref_total / max(best['total_bytes_up'], 1):.1f}x less "
              f"uplink than dense {ref_algo})")

    rec.update({"sweep": {f"{a}+{c}": {
               "final_acc": r["final_acc"],
               "acc": r["acc"],
               "total_bytes_up": r["total_bytes_up"],
               "total_bytes_down": r["total_bytes_down"],
               "total_bytes": r["total_bytes"],
               "bytes_up": r["bytes_up"],
               "bytes_down": r["bytes_down"],
               "compression_ratio": r["compression_ratio"],
               "selected": r["selected"],
               "delivered": r["delivered"],
           } for (a, c), r in recs.items()}})
    if phy:
        rec["phy_sweep"] = phy_sweep(base)
    if byzantine > 0:
        rec["byzantine_sweep"] = byzantine_sweep(base, byzantine)
    if stragglers:
        srec = straggler_sweep(base, algorithms=algorithms)
        srec.update({"n_params": n, "C": C, "rounds": rounds})
        rec["straggler_sweep"] = srec
        STRAGGLER_JSON.write_text(json.dumps(srec, indent=1))
        print(f"straggler record -> {STRAGGLER_JSON}")
    save_record("comm_efficiency", rec)
    if _EM.active:
        _EM.run_end(rounds=0, totals={"cells": float(len(recs))})
        print(f"obs events -> {_EM.path}")
        _EM.close()
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sweep (C=50, 20 rounds)")
    ap.add_argument("--quick", action="store_true",
                    help="explicit quick mode (default unless --full): "
                         "C=10 reduced-width fleet")
    ap.add_argument("--rounds", type=int, default=None,
                    help="override the round count (CI smoke runs)")
    ap.add_argument("--dataset", default="mnist_like")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--aggregator", default="mean",
                    choices=list(AGGREGATORS))
    ap.add_argument("--downlink-compressor", default="identity",
                    choices=list(COMPRESSORS))
    ap.add_argument("--adaptive-bits", action="store_true")
    ap.add_argument("--byzantine", type=int, default=2,
                    help="attackers in the robustness sweep (0 disables)")
    ap.add_argument("--no-phy", action="store_true",
                    help="skip the accuracy-vs-energy phy sweep "
                         "(5 extra runs over the Rayleigh regimes)")
    ap.add_argument("--obs", action="store_true",
                    help="stream per-cell SweepEvents (and per-round "
                         "run streams) under artifacts/obs/")
    ap.add_argument("--json", action="store_true",
                    help="run the straggler sweep (deadline x gamma) "
                         "and write BENCH_stragglers.json at the root")
    args = ap.parse_args()
    if args.full and args.quick:
        ap.error("--full and --quick are mutually exclusive")
    run(quick=not args.full, dataset=args.dataset, seed=args.seed,
        aggregator=args.aggregator,
        downlink_compressor=args.downlink_compressor,
        adaptive_bits=args.adaptive_bits, byzantine=args.byzantine,
        rounds_override=args.rounds, phy=not args.no_phy, obs=args.obs,
        stragglers=args.json)


if __name__ == "__main__":
    main()
