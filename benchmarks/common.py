"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Any

ROOT = Path(__file__).resolve().parents[1]
ARTIFACTS = ROOT / "artifacts"
BENCH_OUT = ARTIFACTS / "bench"


def save_record(name: str, rec: dict[str, Any]) -> Path:
    BENCH_OUT.mkdir(parents=True, exist_ok=True)
    p = BENCH_OUT / f"{name}.json"
    p.write_text(json.dumps(rec, indent=1))
    return p


def load_record(name: str) -> dict[str, Any] | None:
    p = BENCH_OUT / f"{name}.json"
    return json.loads(p.read_text()) if p.exists() else None


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *exc):
        self.seconds = time.time() - self.t0


def print_table(headers: list[str], rows: list[list], title: str = "") -> None:
    if title:
        print(f"\n== {title} ==")
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows
              else len(str(h)) for i, h in enumerate(headers)]
    line = "  ".join(str(h).ljust(w) for h, w in zip(headers, widths))
    print(line)
    print("-" * len(line))
    for r in rows:
        print("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))
