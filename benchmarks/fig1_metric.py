"""Paper Fig. 1 + §V-C: data-heterogeneity quantification.

Sweeps the Dirichlet concentration alpha, measures for each fleet
  * mean label-ratio |L_i|/|L_g|,
  * mean 1-D Wasserstein distance W_i,
  * the fitted non-i.i.d. degree eta (Eq. 2),
and trains FedAvg briefly to get the accuracy trend. Reproduces the
paper's claims that (a) eta tracks the accuracy trend while WD and
label-ratio alone leave gaps, and (b) the least-squares fit of Eq. 2 to
accuracy is strongly linear (paper: R^2 = 0.97 MNIST / 0.895 CIFAR10).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import print_table, save_record
from repro.core import noniid
from repro.data import partition
from repro.data.synthetic import MNIST_LIKE, CIFAR_LIKE

ALPHAS_QUICK = [0.01, 0.1, 0.5, 1.0, 10.0, 100.0]
ALPHAS_FULL = [0.001, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 10.0, 100.0, 1000.0]


def measure_fleet(alpha: float, dataset: str, num_workers: int,
                  seed: int) -> tuple[float, float]:
    spec = MNIST_LIKE if dataset == "mnist_like" else CIFAR_LIKE
    data = partition.dirichlet_partition(
        jax.random.PRNGKey(seed), num_workers, alpha, spec)
    ratios, wds = jax.vmap(
        lambda l: noniid.noniid_features(l, data.global_y, spec.num_classes)
    )(data.y)
    return float(ratios.mean()), float(wds.mean())


def run(quick: bool = True, dataset: str = "mnist_like",
        num_workers: int = 0, rounds: int = 0, seed: int = 0) -> dict:
    alphas = ALPHAS_QUICK if quick else ALPHAS_FULL
    num_workers = num_workers or (10 if quick else 50)
    rounds = rounds or (4 if quick else 10)
    ratios, wds, accs = [], [], []
    for a in alphas:
        r, w = measure_fleet(a, dataset, num_workers, seed)
        rec = _fedavg_at(a, dataset, num_workers, rounds, seed)
        ratios.append(r)
        wds.append(w)
        accs.append(rec["final_acc"])

    ratios_np, wds_np = np.array(ratios), np.array(wds)
    accs_np = np.array(accs)
    coeffs, r2_train, r2_test = noniid.fit_eta_coefficients(
        ratios_np, wds_np, accs_np)
    eta = noniid.minmax_normalize(jnp.asarray(
        coeffs.beta1 * ratios_np + coeffs.beta2 * wds_np + coeffs.phi))
    acc_n = noniid.minmax_normalize(jnp.asarray(accs_np))
    wd_n = noniid.minmax_normalize(jnp.asarray(wds_np))
    ratio_n = noniid.minmax_normalize(jnp.asarray(ratios_np))

    # Fig-1 gap statistics: |metric - normalized accuracy| per alpha
    gap = lambda m: float(jnp.abs(m - acc_n).mean())
    gaps = {"eta": gap(eta), "one_minus_wd": gap(1 - wd_n),
            "label_ratio": gap(ratio_n)}

    rows = [[a, f"{r:.3f}", f"{w:.3f}", f"{ac:.3f}", f"{float(e):.3f}"]
            for a, r, w, ac, e in zip(alphas, ratios, wds, accs, eta)]
    print_table(["alpha", "label_ratio", "WD", "fedavg_acc", "eta"],
                rows, "Fig. 1 — heterogeneity metrics vs FedAvg accuracy")
    print(f"Eq. 2 fit: beta1={coeffs.beta1:.3f} beta2={coeffs.beta2:.3f} "
          f"phi={coeffs.phi:.3f}  R2(train)={r2_train:.3f} "
          f"R2(test)={r2_test:.3f}")
    print(f"mean |metric - acc| gaps (lower = tracks accuracy better): "
          f"eta={gaps['eta']:.3f}  1-WD={gaps['one_minus_wd']:.3f}  "
          f"label-ratio={gaps['label_ratio']:.3f}")

    rec = {"alphas": alphas, "label_ratio": ratios, "wd": wds,
           "fedavg_acc": accs, "eta": np.asarray(eta).tolist(),
           "coeffs": list(coeffs), "r2_train": r2_train, "r2_test": r2_test,
           "gaps": gaps, "dataset": dataset}
    save_record("fig1_metric", rec)
    return rec


def _fedavg_at(alpha, dataset, num_workers, rounds, seed, n_local=256):
    """FedAvg on a Dirichlet(alpha) fleet: alpha is a first-class spec
    axis (data.alpha) — no case-table monkeypatching needed."""
    from repro.experiments import override
    from repro.experiments import run as run_spec
    from repro.experiments.runner import spec_from_paper_kwargs
    spec = spec_from_paper_kwargs(
        algorithm="fedavg", case="noniid1", dataset=dataset, rounds=rounds,
        num_workers=num_workers, width_mult=2, local_epochs=2,
        n_local=n_local, lr=0.05, seed=seed)
    return run_spec(override(spec, f"data.alpha={alpha}"),
                    verbose=False).record


if __name__ == "__main__":
    run()
