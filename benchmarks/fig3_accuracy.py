"""Paper Fig. 3: classification accuracy of FedAvg / DSL / Multi-DSL /
M-DSL under iid, non-iid-I (Dir 0.5) and non-iid-II (mixed fleet).

A thin client of the scenario registry: each case is the
`paper/fig3-<case>` preset, the algorithm axis and the quick-mode
shrink are dotted-path overrides on the spec.

Claims validated:
  * iid is the ceiling all methods approach;
  * under non-iid data M-DSL converges faster and reaches higher accuracy
    than FedAvg and single-best-worker DSL;
  * Multi-DSL (selection without eta) sits between DSL and M-DSL,
    isolating the contribution of the non-i.i.d. degree metric.
"""
from __future__ import annotations

from benchmarks.common import print_table, save_record
from repro.experiments import get_scenario, override
from repro.experiments import run as run_spec

ALGOS = ["fedavg", "dsl", "multi_dsl", "mdsl"]
CASES = ["iid", "noniid1", "noniid2"]

QUICK = ("run.rounds=8", "model.width_mult=2", "algo.local_epochs=1",
         "data.num_workers=10", "data.n_local=256",
         "algo.hp.learning_rate=0.05")


def case_spec(case: str, quick: bool, dataset: str, seed: int):
    spec = get_scenario(f"paper/fig3-{case}")
    if quick:
        spec = override(spec, *QUICK)
    return override(spec, f"data.dataset={dataset}", f"run.seed={seed}")


def run(quick: bool = True, dataset: str = "mnist_like", seed: int = 0
        ) -> dict:
    rounds = 8 if quick else 20
    results: dict = {}
    for case in CASES:
        spec = case_spec(case, quick, dataset, seed)
        for algo in ALGOS:
            rec = run_spec(override(spec, f"algo.algorithm={algo}"),
                           verbose=False).record
            results[f"{algo}/{case}"] = {
                "acc_curve": rec["acc"], "final_acc": rec["final_acc"],
                "best_acc": rec["best_acc"],
                "mean_selected": sum(rec["selected"]) / len(rec["selected"]),
            }
            print(f"  {algo:>9s} / {case:<7s} final_acc="
                  f"{rec['final_acc']:.3f} best={rec['best_acc']:.3f}",
                  flush=True)

    rows = []
    for case in CASES:
        row = [case] + [f"{results[f'{a}/{case}']['final_acc']:.3f}"
                        for a in ALGOS]
        rows.append(row)
    print_table(["case"] + ALGOS, rows,
                f"Fig. 3 — final accuracy ({dataset}, {rounds} rounds)")

    # headline claims as machine-checkable booleans
    claims = {}
    for case in ["noniid1", "noniid2"]:
        m = results[f"mdsl/{case}"]["final_acc"]
        claims[f"mdsl_beats_fedavg_{case}"] = (
            m >= results[f"fedavg/{case}"]["final_acc"] - 0.02)
        claims[f"mdsl_beats_dsl_{case}"] = (
            m >= results[f"dsl/{case}"]["final_acc"] - 0.02)
    print("claims:", claims)
    rec = {"results": results, "claims": claims, "rounds": rounds,
           "dataset": dataset, "quick": quick}
    save_record("fig3_accuracy", rec)
    return rec


if __name__ == "__main__":
    run()
