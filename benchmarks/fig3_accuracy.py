"""Paper Fig. 3: classification accuracy of FedAvg / DSL / Multi-DSL /
M-DSL under iid, non-iid-I (Dir 0.5) and non-iid-II (mixed fleet).

Claims validated:
  * iid is the ceiling all methods approach;
  * under non-iid data M-DSL converges faster and reaches higher accuracy
    than FedAvg and single-best-worker DSL;
  * Multi-DSL (selection without eta) sits between DSL and M-DSL,
    isolating the contribution of the non-i.i.d. degree metric.
"""
from __future__ import annotations

from benchmarks.common import print_table, save_record
from repro.launch.train import run_paper_experiment

ALGOS = ["fedavg", "dsl", "multi_dsl", "mdsl"]
CASES = ["iid", "noniid1", "noniid2"]


def run(quick: bool = True, dataset: str = "mnist_like", seed: int = 0
        ) -> dict:
    rounds = 8 if quick else 20
    width = 2 if quick else 8
    epochs = 1 if quick else 4
    workers = 10 if quick else 50
    n_local = 256 if quick else 512
    results: dict = {}
    for case in CASES:
        for algo in ALGOS:
            rec = run_paper_experiment(
                algorithm=algo, case=case, dataset=dataset, rounds=rounds,
                num_workers=workers, width_mult=width, local_epochs=epochs,
                n_local=n_local, lr=0.05 if quick else 0.01,
                velocity_clip=0.1, seed=seed, verbose=False)
            results[f"{algo}/{case}"] = {
                "acc_curve": rec["acc"], "final_acc": rec["final_acc"],
                "best_acc": rec["best_acc"],
                "mean_selected": sum(rec["selected"]) / len(rec["selected"]),
            }
            print(f"  {algo:>9s} / {case:<7s} final_acc="
                  f"{rec['final_acc']:.3f} best={rec['best_acc']:.3f}",
                  flush=True)

    rows = []
    for case in CASES:
        row = [case] + [f"{results[f'{a}/{case}']['final_acc']:.3f}"
                        for a in ALGOS]
        rows.append(row)
    print_table(["case"] + ALGOS, rows,
                f"Fig. 3 — final accuracy ({dataset}, {rounds} rounds)")

    # headline claims as machine-checkable booleans
    claims = {}
    for case in ["noniid1", "noniid2"]:
        m = results[f"mdsl/{case}"]["final_acc"]
        claims[f"mdsl_beats_fedavg_{case}"] = (
            m >= results[f"fedavg/{case}"]["final_acc"] - 0.02)
        claims[f"mdsl_beats_dsl_{case}"] = (
            m >= results[f"dsl/{case}"]["final_acc"] - 0.02)
    print("claims:", claims)
    rec = {"results": results, "claims": claims, "rounds": rounds,
           "dataset": dataset, "quick": quick}
    save_record("fig3_accuracy", rec)
    return rec


if __name__ == "__main__":
    run()
