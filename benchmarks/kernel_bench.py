"""Pallas-kernel microbenchmarks (CPU container: interpret-mode
correctness + analytic VMEM/roofline accounting; wall-clock here times the
jnp reference, NOT the kernel — real kernel timing needs a TPU).

For each kernel we report, per shape:
  * max |kernel - ref| (interpret mode vs the pure-jnp oracle),
  * the kernel's VMEM working set per grid step (must fit ~16 MiB v5e
    VMEM given the BlockSpec tiling),
  * analytic HBM traffic / FLOPs -> the kernel's v5e roofline bound.

`--json` additionally writes BENCH_wire_path.json at the repo root: the
pinned fused-vs-unfused wire-path numbers (quantize+pack+EF and
dequant+masked-aggregate vs the legacy compress -> decode -> aggregate
chain, per bits x fleet size), asserting bit-identical aggregates and
recording both measured wall-clock (jnp ref implementations on CPU;
compiled pallas where a TPU is attached) and the analytic HBM
bytes-moved reduction the fusion buys. `--quick` shrinks the sweep for
CI. See docs/kernels.md for how to read the artifact.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import ROOT, print_table, save_record
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

KEY = jax.random.PRNGKey(0)

WIRE_JSON = ROOT / "BENCH_wire_path.json"


def _time(fn, reps=3):
    out = fn()  # warm-up/compile
    t0 = time.time()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.time() - t0) / reps


def bench_pso_update() -> list[list]:
    from repro.kernels.pso_update import pso_update, pso_update_ref
    from repro.kernels.pso_update.pso_update import BLOCK_ROWS
    rows = []
    for n in [1 << 14, 1 << 18, 1 << 21]:
        ks = jax.random.split(KEY, 5)
        mk = lambda k: {"a": jax.random.normal(k, (n,))}
        w, v, wl, wg, d = (mk(k) for k in ks)
        w2, v2 = pso_update(w, v, wl, wg, d, 0.7, 0.2, -0.4, clip=1.0,
                            interpret=True)
        coefs = jnp.array([0.7, 0.2, -0.4, 1.0])
        wr, vr = pso_update_ref(coefs, w["a"], v["a"], wl["a"], wg["a"],
                                d["a"])
        err = max(float(jnp.abs(w2["a"] - wr).max()),
                  float(jnp.abs(v2["a"] - vr).max()))
        hbm = 7 * n * 4               # 5 reads + 2 writes, fp32
        vmem = 7 * BLOCK_ROWS * 128 * 4
        t_ref = _time(lambda: pso_update_ref(coefs, w["a"], v["a"],
                                             wl["a"], wg["a"], d["a"]))
        rows.append(["pso_update", f"n={n}", f"{err:.2e}",
                     f"{vmem / 2**10:.0f}KiB",
                     f"{hbm / HBM_BW * 1e6:.1f}us (mem)",
                     f"{t_ref * 1e3:.2f}ms"])
    return rows


def bench_flash_attention() -> list[list]:
    from repro.kernels.flash_attention import attention_ref, flash_attention
    rows = []
    for (b, s, h, kv, hd, w) in [(1, 256, 4, 2, 64, 0),
                                 (1, 512, 2, 2, 64, 128)]:
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, s, h, hd))
        k = jax.random.normal(ks[1], (b, s, kv, hd))
        v = jax.random.normal(ks[2], (b, s, kv, hd))
        out = flash_attention(q, k, v, causal=True, window=w,
                              interpret=True)
        g = h // kv
        qr = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
        kr = jnp.repeat(k.transpose(0, 2, 1, 3), g, 1).reshape(b * h, s, hd)
        vr = jnp.repeat(v.transpose(0, 2, 1, 3), g, 1).reshape(b * h, s, hd)
        ref = attention_ref(qr, kr, vr, causal=True, window=w)
        ref = ref.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
        err = float(jnp.abs(out - ref).max())
        blk_q = blk_k = 128
        vmem = (blk_q * hd + 2 * blk_k * hd + blk_q * blk_k + blk_q * hd) * 4
        frac = 0.5 if w == 0 else min(1.0, w / s)
        flops = 4 * b * h * s * s * hd * frac
        t_comp = flops / PEAK_FLOPS_BF16
        t_ref = _time(lambda: attention_ref(qr, kr, vr, causal=True,
                                            window=w))
        rows.append([f"flash_attn{'(swa)' if w else ''}",
                     f"b{b}s{s}h{h}kv{kv}d{hd}" + (f"w{w}" if w else ""),
                     f"{err:.2e}", f"{vmem / 2**10:.0f}KiB",
                     f"{t_comp * 1e6:.2f}us (mxu)",
                     f"{t_ref * 1e3:.2f}ms"])
    return rows


def bench_quant_pack() -> list[list]:
    from repro.kernels.quant_pack import (dequant_unpack_ref, quant_pack_2d,
                                          quant_pack_ref)
    from repro.kernels.quant_pack.quant_pack import BLOCK_ROWS
    rows = []
    for n, bits in [(1 << 16, 8), (1 << 20, 8), (1 << 20, 4)]:
        x = jax.random.normal(KEY, (n // 128, 128))
        seed = jnp.int32(7)
        pk, sk = quant_pack_2d(x, seed, bits=bits, interpret=True)
        pr, sr = quant_pack_ref(x, seed, bits=bits)
        # kernel vs oracle must be bit-identical (shared hash RNG)
        err = max(float(jnp.abs(pk.astype(jnp.int32)
                                - pr.astype(jnp.int32)).max()),
                  float(jnp.abs(sk - sr).max()))
        # sanity: the round trip stays within one quantization step
        xh = dequant_unpack_ref(pr, sr, bits=bits)
        qmax = 127.0 if bits == 8 else 7.0
        assert float(jnp.abs(xh - x).max()) <= float(
            jnp.abs(x).max()) / qmax + 1e-6
        hbm = n * 4 + n * bits // 8   # read f32, write packed (+scales)
        vmem = int((4 + bits / 8 + 1) * BLOCK_ROWS * 128)
        t_ref = _time(lambda: quant_pack_ref(x, seed, bits=bits))
        rows.append([f"quant_pack(int{bits})", f"n={n}", f"{err:.2e}",
                     f"{vmem / 2**10:.0f}KiB",
                     f"{hbm / HBM_BW * 1e6:.1f}us (mem)",
                     f"{t_ref * 1e3:.2f}ms"])
    return rows


def bench_rglru() -> list[list]:
    from repro.kernels.rglru_scan import rglru_scan, rglru_scan_ref
    rows = []
    for (b, s, d) in [(2, 256, 128), (1, 1024, 256)]:
        ks = jax.random.split(KEY, 3)
        a = jax.random.uniform(ks[0], (b, s, d), minval=0.5, maxval=0.999)
        x = 0.1 * jax.random.normal(ks[1], (b, s, d))
        h0 = jax.random.normal(ks[2], (b, d))
        out, fin = rglru_scan(h0, a, x, interpret=True)
        ref = rglru_scan_ref(h0, a, x)
        err = float(jnp.abs(out - ref).max())
        hbm = 3 * b * s * d * 4       # read a,b + write h, fp32
        chunk = 128
        vmem = 3 * chunk * d * 4
        t_ref = _time(lambda: rglru_scan_ref(h0, a, x))
        rows.append(["rglru_scan", f"b{b}s{s}d{d}", f"{err:.2e}",
                     f"{vmem / 2**10:.0f}KiB",
                     f"{hbm / HBM_BW * 1e6:.1f}us (mem)",
                     f"{t_ref * 1e3:.2f}ms"])
    return rows


def bench_wire_kernels(quick: bool = False) -> list[list]:
    """Interpret-mode correctness + roofline rows for the fused
    wire-path pair (quant_pack_ef, wire_agg)."""
    from repro.kernels.quant_pack import quant_pack_ef_2d, quant_pack_ef_ref
    from repro.kernels.quant_pack.quant_pack import BLOCK_ROWS
    from repro.kernels.wire_agg import wire_agg_2d, wire_agg_ref
    rows = []
    n, C = (1 << 15, 4) if quick else (1 << 16, 8)
    x = jax.random.normal(KEY, (n // 128, 128))
    r = 0.05 * jax.random.normal(jax.random.fold_in(KEY, 1), (n // 128, 128))
    seed = jnp.int32(7)
    for bits in (8, 4):
        pk, sk, rk = quant_pack_ef_2d(x, r, seed, bits=bits, interpret=True)
        pr, sr, rr = quant_pack_ef_ref(x, r, seed, bits=bits)
        err = max(float(jnp.abs(pk.astype(jnp.int32)
                                - pr.astype(jnp.int32)).max()),
                  float(jnp.abs(sk - sr).max()),
                  float(jnp.abs(rk - rr).max()))
        # read delta+residual (8B/elem), write packed + new residual
        hbm = 8 * n + n * bits // 8 + 4 * n
        vmem = int((4 + 4 + bits / 8 + 4) * BLOCK_ROWS * 128)
        t_ref = _time(lambda: quant_pack_ef_ref(x, r, seed, bits=bits))
        rows.append([f"quant_pack_ef(int{bits})", f"n={n}", f"{err:.2e}",
                     f"{vmem / 2**10:.0f}KiB",
                     f"{hbm / HBM_BW * 1e6:.1f}us (mem)",
                     f"{t_ref * 1e3:.2f}ms"])

    from repro.kernels.quant_pack import quant_pack_ref
    mask = (jnp.arange(C) % 4 != 3).astype(jnp.float32).reshape(C, 1)
    w1 = jnp.ones((C, 1), jnp.float32)
    for bits in (8, 4):
        xs = jax.random.normal(jax.random.fold_in(KEY, 2), (C, n // 128,
                                                            128))
        pcs = [quant_pack_ref(xs[c], jnp.int32(c + 1), bits=bits)
               for c in range(C)]
        packed = jnp.stack([p for p, _ in pcs])
        scales = jnp.stack([s for _, s in pcs])
        for agg in (("mean",) if quick else ("mean", "median")):
            a_k = wire_agg_2d(packed, scales, mask, w1, bits=bits,
                              aggregator=agg, interpret=True)
            ref_fn = jax.jit(lambda p, s, m, w: wire_agg_ref(
                p, s, m, w, bits=bits, aggregator=agg))
            a_r = ref_fn(packed, scales, mask, w1)
            err = float(jnp.abs(a_k - a_r).max())
            hbm = C * (n * bits // 8) + 4 * n   # read C packed, write f32
            vmem = int(C * BLOCK_ROWS * 128 * (bits / 8 + 4)
                       + BLOCK_ROWS * 128 * 4)
            t_ref = _time(lambda: ref_fn(packed, scales, mask, w1))
            rows.append([f"wire_agg(int{bits},{agg})", f"C={C} n={n}",
                         f"{err:.2e}", f"{vmem / 2**10:.0f}KiB",
                         f"{hbm / HBM_BW * 1e6:.1f}us (mem)",
                         f"{t_ref * 1e3:.2f}ms"])
    return rows


def _wire_path_cell(bits: int, C: int, n: int) -> dict:
    """One pinned wire-path cell: the fused two-pass route vs the legacy
    unfused compress -> decode -> EF-subtract -> aggregate chain, both
    as jitted jnp implementations (what actually runs on this CPU
    container; on TPU the same call sites dispatch to compiled pallas).
    Asserts bit-identical aggregate + residual, times both, and records
    the analytic HBM bytes each route moves on TPU."""
    from repro.kernels.quant_pack import (dequant_unpack_ref,
                                          quant_pack_ef_ref, quant_pack_ref)
    from repro.kernels.wire_agg import wire_agg_ref
    rows_2d = n // 128
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, bits * 100 + C))
    delta = jax.random.normal(k1, (C, rows_2d, 128))
    residual = 0.05 * jax.random.normal(k2, (C, rows_2d, 128))
    seeds = jnp.arange(C, dtype=jnp.int32) + 11
    mask = (jnp.arange(C) % 4 != 3).astype(jnp.float32)

    @jax.jit
    def unfused(delta, residual, mask, seeds):
        def one(x, r, s):
            p, sc = quant_pack_ref(x + r, s, bits=bits)
            wire = dequant_unpack_ref(p, sc, bits=bits)
            return wire, (x + r) - wire

        wire, res = jax.vmap(one)(delta, residual, seeds)
        m = mask[:, None, None]
        agg = (m * wire).sum(axis=0) / jnp.maximum(mask.sum(), 1.0)
        return agg, res

    @jax.jit
    def fused(delta, residual, mask, seeds):
        p, sc, res = jax.vmap(
            lambda x, r, s: quant_pack_ef_ref(x, r, s, bits=bits))(
                delta, residual, seeds)
        agg = wire_agg_ref(p, sc, mask.reshape(C, 1),
                           jnp.ones((C, 1), jnp.float32), bits=bits)
        return agg, res

    agg_u, res_u = unfused(delta, residual, mask, seeds)
    agg_f, res_f = fused(delta, residual, mask, seeds)
    bit_identical = bool(np.array_equal(np.asarray(agg_u), np.asarray(agg_f))
                         and np.array_equal(np.asarray(res_u),
                                            np.asarray(res_f)))
    t_u = _time(lambda: unfused(delta, residual, mask, seeds))
    t_f = _time(lambda: fused(delta, residual, mask, seeds))
    # analytic HBM bytes per leaf-round (see docs/kernels.md):
    # unfused = EF-add 12n + pack 4n + b n/8 + decode b n/8 + 4n +
    #           EF-subtract 12n per worker, + aggregate C*4n read + 4n
    # fused   = one 8n read + b n/8 + 4n write per worker, + aggregate
    #           C * b n/8 read + 4n
    hbm_u = C * (36 * n + bits * n // 4) + 4 * n
    hbm_f = C * (12 * n + bits * n // 4) + 4 * n
    return {"bits": bits, "workers": C, "n": n, "aggregator": "mean",
            "t_unfused_ms": round(t_u * 1e3, 3),
            "t_fused_ms": round(t_f * 1e3, 3),
            "speedup": round(t_u / t_f, 3),
            "hbm_unfused_bytes": hbm_u, "hbm_fused_bytes": hbm_f,
            "hbm_reduction": round(hbm_u / hbm_f, 3),
            "bit_identical": bit_identical}


def bench_wire_path(quick: bool = False) -> dict:
    """The pinned perf artifact: fused vs unfused wire path per
    bits x fleet size. Returns the BENCH_wire_path.json record."""
    n = (1 << 16) if quick else (1 << 19)
    fleets = (4, 8) if quick else (4, 16, 32)
    cells = [_wire_path_cell(bits, C, n)
             for bits in (8, 4) for C in fleets]
    rec = {
        "schema": 1,
        "backend": jax.default_backend(),
        "mode": "jnp-ref",   # CPU: both routes measured as jitted jnp;
        #                      a TPU run times compiled pallas instead
        "quick": quick,
        "hbm_model": ("bytes per leaf-round: unfused C*(36n + b*n/4) + 4n"
                      " vs fused C*(12n + b*n/4) + 4n"),
        "rows": cells,
    }
    print_table(
        ["bits", "C", "n", "t_unfused", "t_fused", "speedup", "HBM x",
         "bit-identical"],
        [[c["bits"], c["workers"], c["n"], f"{c['t_unfused_ms']:.1f}ms",
          f"{c['t_fused_ms']:.1f}ms", f"{c['speedup']:.2f}x",
          f"{c['hbm_reduction']:.2f}x", c["bit_identical"]]
         for c in cells],
        "Wire path — fused (quant_pack_ef + wire_agg) vs unfused jnp")
    return rec


def run(quick: bool = False, write_json: bool = False) -> dict:
    rows = (bench_pso_update() + bench_flash_attention() + bench_rglru()
            + bench_quant_pack() + bench_wire_kernels(quick))
    print_table(["kernel", "shape", "max|err|", "VMEM/step", "v5e bound",
                 "CPU ref time"], rows,
                "Pallas kernels — interpret-mode correctness + roofline")
    bad = [r for r in rows if float(r[2]) > 1e-3]
    wire = bench_wire_path(quick)
    rec = {"rows": rows, "all_correct": not bad, "wire_path": wire}
    save_record("kernel_bench", rec)
    if write_json:
        WIRE_JSON.write_text(json.dumps(wire, indent=1))
        print(f"wrote {WIRE_JSON}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small shapes / fewer cells (CI)")
    ap.add_argument("--json", action="store_true",
                    help=f"write the pinned wire-path record to {WIRE_JSON}")
    args = ap.parse_args()
    run(quick=args.quick, write_json=args.json)


if __name__ == "__main__":
    main()
