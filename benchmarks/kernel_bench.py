"""Pallas-kernel microbenchmarks (CPU container: interpret-mode
correctness + analytic VMEM/roofline accounting; wall-clock here times the
jnp reference, NOT the kernel — real kernel timing needs a TPU).

For each kernel we report, per shape:
  * max |kernel - ref| (interpret mode vs the pure-jnp oracle),
  * the kernel's VMEM working set per grid step (must fit ~16 MiB v5e
    VMEM given the BlockSpec tiling),
  * analytic HBM traffic / FLOPs -> the kernel's v5e roofline bound.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import print_table, save_record
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

KEY = jax.random.PRNGKey(0)


def _time(fn, reps=3):
    out = fn()  # warm-up/compile
    t0 = time.time()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.time() - t0) / reps


def bench_pso_update() -> list[list]:
    from repro.kernels.pso_update import pso_update, pso_update_ref
    from repro.kernels.pso_update.pso_update import BLOCK_ROWS
    rows = []
    for n in [1 << 14, 1 << 18, 1 << 21]:
        ks = jax.random.split(KEY, 5)
        mk = lambda k: {"a": jax.random.normal(k, (n,))}
        w, v, wl, wg, d = (mk(k) for k in ks)
        w2, v2 = pso_update(w, v, wl, wg, d, 0.7, 0.2, -0.4, clip=1.0,
                            interpret=True)
        coefs = jnp.array([0.7, 0.2, -0.4, 1.0])
        wr, vr = pso_update_ref(coefs, w["a"], v["a"], wl["a"], wg["a"],
                                d["a"])
        err = max(float(jnp.abs(w2["a"] - wr).max()),
                  float(jnp.abs(v2["a"] - vr).max()))
        hbm = 7 * n * 4               # 5 reads + 2 writes, fp32
        vmem = 7 * BLOCK_ROWS * 128 * 4
        t_ref = _time(lambda: pso_update_ref(coefs, w["a"], v["a"],
                                             wl["a"], wg["a"], d["a"]))
        rows.append(["pso_update", f"n={n}", f"{err:.2e}",
                     f"{vmem / 2**10:.0f}KiB",
                     f"{hbm / HBM_BW * 1e6:.1f}us (mem)",
                     f"{t_ref * 1e3:.2f}ms"])
    return rows


def bench_flash_attention() -> list[list]:
    from repro.kernels.flash_attention import attention_ref, flash_attention
    rows = []
    for (b, s, h, kv, hd, w) in [(1, 256, 4, 2, 64, 0),
                                 (1, 512, 2, 2, 64, 128)]:
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, s, h, hd))
        k = jax.random.normal(ks[1], (b, s, kv, hd))
        v = jax.random.normal(ks[2], (b, s, kv, hd))
        out = flash_attention(q, k, v, causal=True, window=w,
                              interpret=True)
        g = h // kv
        qr = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
        kr = jnp.repeat(k.transpose(0, 2, 1, 3), g, 1).reshape(b * h, s, hd)
        vr = jnp.repeat(v.transpose(0, 2, 1, 3), g, 1).reshape(b * h, s, hd)
        ref = attention_ref(qr, kr, vr, causal=True, window=w)
        ref = ref.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
        err = float(jnp.abs(out - ref).max())
        blk_q = blk_k = 128
        vmem = (blk_q * hd + 2 * blk_k * hd + blk_q * blk_k + blk_q * hd) * 4
        frac = 0.5 if w == 0 else min(1.0, w / s)
        flops = 4 * b * h * s * s * hd * frac
        t_comp = flops / PEAK_FLOPS_BF16
        t_ref = _time(lambda: attention_ref(qr, kr, vr, causal=True,
                                            window=w))
        rows.append([f"flash_attn{'(swa)' if w else ''}",
                     f"b{b}s{s}h{h}kv{kv}d{hd}" + (f"w{w}" if w else ""),
                     f"{err:.2e}", f"{vmem / 2**10:.0f}KiB",
                     f"{t_comp * 1e6:.2f}us (mxu)",
                     f"{t_ref * 1e3:.2f}ms"])
    return rows


def bench_quant_pack() -> list[list]:
    from repro.kernels.quant_pack import (dequant_unpack_ref, quant_pack_2d,
                                          quant_pack_ref)
    from repro.kernels.quant_pack.quant_pack import BLOCK_ROWS
    rows = []
    for n, bits in [(1 << 16, 8), (1 << 20, 8), (1 << 20, 4)]:
        x = jax.random.normal(KEY, (n // 128, 128))
        seed = jnp.int32(7)
        pk, sk = quant_pack_2d(x, seed, bits=bits, interpret=True)
        pr, sr = quant_pack_ref(x, seed, bits=bits)
        # kernel vs oracle must be bit-identical (shared hash RNG)
        err = max(float(jnp.abs(pk.astype(jnp.int32)
                                - pr.astype(jnp.int32)).max()),
                  float(jnp.abs(sk - sr).max()))
        # sanity: the round trip stays within one quantization step
        xh = dequant_unpack_ref(pr, sr, bits=bits)
        qmax = 127.0 if bits == 8 else 7.0
        assert float(jnp.abs(xh - x).max()) <= float(
            jnp.abs(x).max()) / qmax + 1e-6
        hbm = n * 4 + n * bits // 8   # read f32, write packed (+scales)
        vmem = int((4 + bits / 8 + 1) * BLOCK_ROWS * 128)
        t_ref = _time(lambda: quant_pack_ref(x, seed, bits=bits))
        rows.append([f"quant_pack(int{bits})", f"n={n}", f"{err:.2e}",
                     f"{vmem / 2**10:.0f}KiB",
                     f"{hbm / HBM_BW * 1e6:.1f}us (mem)",
                     f"{t_ref * 1e3:.2f}ms"])
    return rows


def bench_rglru() -> list[list]:
    from repro.kernels.rglru_scan import rglru_scan, rglru_scan_ref
    rows = []
    for (b, s, d) in [(2, 256, 128), (1, 1024, 256)]:
        ks = jax.random.split(KEY, 3)
        a = jax.random.uniform(ks[0], (b, s, d), minval=0.5, maxval=0.999)
        x = 0.1 * jax.random.normal(ks[1], (b, s, d))
        h0 = jax.random.normal(ks[2], (b, d))
        out, fin = rglru_scan(h0, a, x, interpret=True)
        ref = rglru_scan_ref(h0, a, x)
        err = float(jnp.abs(out - ref).max())
        hbm = 3 * b * s * d * 4       # read a,b + write h, fp32
        chunk = 128
        vmem = 3 * chunk * d * 4
        t_ref = _time(lambda: rglru_scan_ref(h0, a, x))
        rows.append(["rglru_scan", f"b{b}s{s}d{d}", f"{err:.2e}",
                     f"{vmem / 2**10:.0f}KiB",
                     f"{hbm / HBM_BW * 1e6:.1f}us (mem)",
                     f"{t_ref * 1e3:.2f}ms"])
    return rows


def run() -> dict:
    rows = (bench_pso_update() + bench_flash_attention() + bench_rglru()
            + bench_quant_pack())
    print_table(["kernel", "shape", "max|err|", "VMEM/step", "v5e bound",
                 "CPU ref time"], rows,
                "Pallas kernels — interpret-mode correctness + roofline")
    bad = [r for r in rows if float(r[2]) > 1e-3]
    rec = {"rows": rows, "all_correct": not bad}
    save_record("kernel_bench", rec)
    return rec


if __name__ == "__main__":
    run()
