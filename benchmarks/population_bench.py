"""Population-engine scaling: round cost vs registered fleet size.

The sampled-cohort engine (core/population.py) holds O(P) persistent
scalars but does O(K) work per round, so the per-round wall-time curve
over P ∈ {1k, 100k, 1M} at fixed K should be flat — the training round
dominates and the schedule (Gumbel-top-k over P) plus scatter (K-row
writes into the (P,) columns) stay in the noise. This benchmark pins
that curve:

  * per-round wall time of the full wrapped step (schedule + reseat +
    inner round + scatter), averaged over timed rounds after warm-up;
  * the isolated schedule / scatter cost at each P;
  * the registry footprint (36 B/device).

`--json` writes BENCH_population.json at the repo root (the CI
population-smoke job asserts its shape); `--quick` times fewer rounds.
CPU container numbers time jnp/XLA-CPU — the curve's SHAPE (flat in P
for the round, sub-linear growth only in the O(P) schedule reduction)
is the pinned claim, not the absolute milliseconds.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from benchmarks.common import ROOT, print_table, save_record
from repro.core import population as pop
from repro.experiments.registry import get_scenario
from repro.experiments.runner import build
from repro.experiments.spec import override

POPULATIONS = (1_000, 100_000, 1_000_000)
JSON_OUT = ROOT / "BENCH_population.json"


def _time(fn, reps: int) -> float:
    jax.block_until_ready(fn())      # warm-up / compile
    t0 = time.time()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.time() - t0) / reps


def bench_population(P: int, rounds: int, reps: int) -> dict:
    spec = override(get_scenario("fleet/million-uniform"),
                    f"fleet.population={P}", f"run.rounds={rounds}")
    t0 = time.time()
    prep = build(spec)
    build_s = time.time() - t0
    K = spec.data.num_workers
    comm = spec.comm

    # isolated O(P)-facing pieces: the jitted sampler+gather and the
    # K-row scatter against a P-wide table
    table = prep.state.table
    sched = lambda: pop.schedule(table, jnp.int32(1),
                                 jax.random.PRNGKey(0), comm=comm,
                                 cohort_size=K, policy="uniform")
    schedule_s = _time(sched, reps)
    idx, phy = jax.tree.map(jax.block_until_ready, sched())
    theta = jnp.zeros((K,), jnp.float32)
    scatter_s = _time(
        lambda: pop.scatter_round(table, idx, phy, theta, theta,
                                  jnp.int32(1)), reps)

    # full wrapped rounds: first is compile + warm-up, rest are timed
    state, key = prep.state, prep.key
    round_times = []
    for t in range(rounds):
        t1 = time.time()
        state, metrics, key = prep.step(state, key)
        jax.block_until_ready(metrics.global_loss)
        round_times.append(time.time() - t1)
    timed = round_times[1:] or round_times
    return {"population": P, "cohort": K,
            "round_s": round(sum(timed) / len(timed), 4),
            "round0_s": round(round_times[0], 4),
            "schedule_s": round(schedule_s, 6),
            "scatter_s": round(scatter_s, 6),
            "table_mb": round(pop.table_bytes(table) / 1e6, 2),
            "build_s": round(build_s, 2),
            "final_loss": float(metrics.global_loss)}


def run(quick: bool = False, write_json: bool = False) -> dict:
    rounds = 2 if quick else 4
    reps = 3 if quick else 10
    rows = [bench_population(P, rounds, reps) for P in POPULATIONS]
    print_table(
        ["P", "K", "round_s", "schedule_s", "scatter_s", "table_mb"],
        [[r["population"], r["cohort"], r["round_s"], r["schedule_s"],
          r["scatter_s"], r["table_mb"]] for r in rows],
        "Population engine — per-round cost vs registered fleet size")
    rec = {"schema": 1, "fixed_cohort": rows[0]["cohort"],
           "quick": quick, "rows": rows}
    save_record("population_bench", rec)
    if write_json:
        JSON_OUT.write_text(json.dumps(rec, indent=1))
        print(f"wrote {JSON_OUT}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="fewer timed rounds/reps (CI)")
    ap.add_argument("--json", action="store_true",
                    help=f"write the pinned scaling record to {JSON_OUT}")
    args = ap.parse_args()
    run(quick=args.quick, write_json=args.json)


if __name__ == "__main__":
    main()
