"""§Roofline deliverable: per (arch x shape) three-term roofline from the
dry-run artifacts (single-pod 16x16 mesh), per the brief:

    compute term    = true_FLOPs / peak_FLOP/s       (per-device program)
    memory term     = HBM_bytes  / HBM_bw
    collective term = collective_bytes / link_bw

plus the dominant term, MODEL_FLOPS/HLO_FLOPs utilization ratio and the
multi-pod lowering status. Reads artifacts/dryrun/*.json (produced by
`python -m repro.launch.dryrun --all --mesh both`).

The `mem_fa` column re-derives the memory term assuming the Pallas
flash-attention kernel (kernels/flash_attention) replaces the reference
chunked attention on the TPU target: the S_q x S_k score/probability
matrices then live in VMEM scratch and never touch HBM, so their traffic
is subtracted analytically. The dry-run compiles the reference path (the
host backend cannot lower Pallas), so the raw memory term is an upper
bound for attention-heavy prefill/train shapes.
"""
from __future__ import annotations

import json

from benchmarks.common import ARTIFACTS, print_table, save_record
from repro.configs.base import INPUT_SHAPES, get_arch
from repro.launch.mesh import HBM_BW

DRYRUN = ARTIFACTS / "dryrun"
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
MESH_DATA, MESH_MODEL = 16, 16


def _attn_score_bytes(arch: str, shape_name: str) -> float:
    """Per-device HBM bytes the score/prob matrices cost WITHOUT the
    flash kernel (write+read of scores and probs, fwd; x2 more for the
    remat-recomputed fwd + bwd at train)."""
    cfg = get_arch(arch)
    shape = INPUT_SHAPES[shape_name]
    if shape.kind == "decode":
        return 0.0  # one-token attention reads the cache either way
    n_attn = sum(cfg.block_pattern[i % len(cfg.block_pattern)]
                 in ("attn", "swa") for i in range(cfg.num_layers))
    if not n_attn:
        return 0.0
    S = shape.seq_len
    Sk = min(cfg.window_size, S) if cfg.window_size else S
    # local batch: train shards batch over data via the worker/batch axis;
    # serve shards batch over data
    b_local = max(shape.global_batch // MESH_DATA, 1)
    heads = cfg.num_heads
    h_local = heads // MESH_MODEL if heads % MESH_MODEL == 0 else heads
    passes = 3 if shape.kind == "train" else 1   # fwd + recompute + bwd
    # scores + probs, written and read once each, f32
    per_layer = 2 * 2 * b_local * h_local * S * Sk * 4
    total = n_attn * per_layer * passes
    if cfg.encoder_layers and shape.kind == "train":
        total += cfg.encoder_layers * 2 * 2 * b_local * heads * \
            cfg.encoder_memory_len ** 2 * 4 * passes
    return float(total)


def load(arch: str, shape: str, mesh: str, tag: str = "") -> dict | None:
    p = DRYRUN / f"{arch}__{shape}__{mesh}{tag}.json"
    return json.loads(p.read_text()) if p.exists() else None


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def run(tag: str = "") -> dict:
    archs = sorted({p.name.split("__")[0] for p in DRYRUN.glob("*.json")})
    rows, table = [], {}
    for arch in archs:
        for shape in SHAPES:
            rec = load(arch, shape, "single", tag)
            if rec is None:
                continue
            multi = load(arch, shape, "multi", tag)
            multi_ok = ("skip" if (multi or {}).get("skipped")
                        else "ok" if (multi or {}).get("ok") else "MISSING")
            if rec.get("skipped"):
                rows.append([arch, shape, "SKIP", "-", "-", "-", "-", "-",
                             "-", multi_ok])
                table[f"{arch}/{shape}"] = {"skipped": True,
                                            "reason": rec.get("reason")}
                continue
            if not rec.get("ok"):
                rows.append([arch, shape, "FAIL", "-", "-", "-", "-", "-",
                             "-", multi_ok])
                continue
            r = rec["roofline"]
            dom = r["dominant"].replace("_s", "")
            mem_fa = max(r["memory_s"]
                         - _attn_score_bytes(arch, shape) / HBM_BW, 0.0)
            rows.append([
                arch, shape, fmt_s(r["compute_s"]), fmt_s(r["memory_s"]),
                fmt_s(mem_fa), fmt_s(r["collective_s"]), dom,
                f"{r['useful_flops_ratio']:.2f}",
                fmt_s(r["bound_step_s"]), multi_ok])
            table[f"{arch}/{shape}"] = {
                "compute_s": r["compute_s"], "memory_s": r["memory_s"],
                "memory_flash_s": mem_fa,
                "collective_s": r["collective_s"], "dominant": dom,
                "useful_flops_ratio": r["useful_flops_ratio"],
                "bound_step_s": r["bound_step_s"], "multi_pod": multi_ok,
                "collective_breakdown": rec["collectives"]["by_kind_bytes"],
                "memory": rec.get("memory"),
                "host_f32_inflation_bytes":
                    rec.get("host_f32_inflation_bytes", 0),
            }
    print_table(
        ["arch", "shape", "t_compute", "t_memory", "mem_fa", "t_coll",
         "dominant", "useful", "bound", "multi-pod"],
        rows, f"Roofline (single-pod 16x16, v5e){tag and ' tag=' + tag}")
    rec = {"table": table, "tag": tag}
    save_record(f"roofline{tag}", rec)
    return rec


if __name__ == "__main__":
    run()
