"""Benchmark harness entry point: one benchmark per paper table/figure
plus the roofline and kernel reports.

  fig1   — Fig. 1 + §V-C: non-i.i.d. degree metric vs WD / label-ratio,
           least-squares fit R^2
  fig3   — Fig. 3: FedAvg / DSL / Multi-DSL / M-DSL accuracy under
           iid / non-iid I / non-iid II
  comm   — §IV-C: uploaded parameters per round, rounds-to-accuracy
  roofline — §Roofline tables from the dry-run artifacts
  kernels  — Pallas kernel correctness + VMEM/roofline accounting

`python -m benchmarks.run` runs everything in quick mode (CPU-sized);
`--full` uses the paper's settings (50 workers, 20/40 rounds);
`--only fig3,comm` selects a subset.
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list of fig1,fig3,comm,roofline,kernels")
    ap.add_argument("--dataset", default="mnist_like",
                    choices=["mnist_like", "cifar_like"])
    args = ap.parse_args()
    quick = not args.full
    sel = set(args.only.split(",")) if args.only else {
        "fig1", "fig3", "comm", "roofline", "kernels"}

    t0 = time.time()
    if "kernels" in sel:
        from benchmarks import kernel_bench
        kernel_bench.run()
    if "roofline" in sel:
        from benchmarks import roofline
        roofline.run()
    if "fig1" in sel:
        from benchmarks import fig1_metric
        fig1_metric.run(quick=quick, dataset=args.dataset)
    if "comm" in sel:
        from benchmarks import comm_efficiency
        comm_efficiency.run(quick=quick, dataset=args.dataset)
    if "fig3" in sel:
        from benchmarks import fig3_accuracy
        fig3_accuracy.run(quick=quick, dataset=args.dataset)
    print(f"\nbenchmarks done in {time.time() - t0:.0f}s "
          f"({'quick' if quick else 'full'} mode)")


if __name__ == "__main__":
    main()
