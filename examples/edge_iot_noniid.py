"""End-to-end edge-IoT comparison on the scenario registry: M-DSL vs
FedAvg on the paper's heterogeneous fleet (non-iid case II, Fig. 2 —
mixed Dirichlet alphas). One preset, one override per algorithm, one
`run()` each; prints convergence curves and the communication saving,
and checkpoints the winning global model.

    PYTHONPATH=src python examples/edge_iot_noniid.py [--rounds 8]
    [--workers 10] [--dataset mnist_like]
"""
import argparse
from pathlib import Path

from repro.checkpoint import CheckpointManager
from repro.experiments import get_scenario, override, run


def ascii_curve(vals, width=40, lo=0.0, hi=1.0):
    out = []
    for v in vals:
        n = int((v - lo) / (hi - lo) * width)
        out.append("|" + "#" * n + " " * (width - n) + f"| {v:.3f}")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--workers", type=int, default=10)
    ap.add_argument("--dataset", default="mnist_like")
    ap.add_argument("--width-mult", type=int, default=2)
    args = ap.parse_args()

    base = override(get_scenario("edge-iot/noniid2"),
                    f"run.rounds={args.rounds}",
                    f"data.num_workers={args.workers}",
                    f"data.dataset={args.dataset}",
                    f"model.width_mult={args.width_mult}")

    runs = {}
    for algo in ["fedavg", "mdsl"]:
        print(f"\n=== {algo} on non-iid case II "
              f"({args.workers} workers) ===")
        runs[algo] = run(override(base, f"algo.algorithm={algo}")).record

    for algo, rec in runs.items():
        print(f"\n{algo} accuracy per round:")
        print(ascii_curve(rec["acc"]))

    fed, md = runs["fedavg"], runs["mdsl"]
    n, C, R = md["n_params"], args.workers, args.rounds
    saving = 1 - md["total_uploaded_params"] / (n * C * R)
    print(f"\nfinal acc: fedavg {fed['final_acc']:.3f}  "
          f"mdsl {md['final_acc']:.3f}")
    print(f"M-DSL upload saving vs FedAvg: {100 * saving:.1f}% "
          f"(mean {sum(md['selected']) / R:.1f}/{C} workers/round)")

    # checkpoint the better model's metrics record
    ckpt_dir = Path("artifacts/examples/edge_iot")
    mgr = CheckpointManager(ckpt_dir, max_to_keep=2)
    import jax.numpy as jnp
    mgr.save(args.rounds, {"acc": jnp.asarray(md["acc"])},
             metadata={"algorithm": "mdsl", "final_acc": md["final_acc"]})
    print(f"checkpointed to {ckpt_dir}")


if __name__ == "__main__":
    main()
