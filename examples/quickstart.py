"""Quickstart: the paper's pipeline through the experiment front door.

Every run is a declarative `ExperimentSpec`: look a scenario up in the
registry, `override()` the axes you care about, `run()` it. The result
carries the full spec next to the metrics, so it can be re-run or
swept verbatim.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.experiments import get_scenario, override, run, to_dict

# --- 1. a named scenario: 8-worker non-iid fleet, small paper CNN ----------
spec = get_scenario("quickstart")
print("scenario:", spec.name, "->", to_dict(spec)["data"])

# --- 2. tweak one axis the declarative way (sweeps are just strings) -------
spec = override(spec, "run.rounds=4", "comm.compressor=int8")

# --- 3. run it: M-DSL rounds (Algorithm 1) with selection + wire metrics ---
result = run(spec)

rec = result.record
print(f"\nmodel: {rec['model']}, {rec['n_params']:,} params, "
      f"{rec['num_workers']} workers")
print("per-worker non-i.i.d. degree eta:",
      [f"{e:.2f}" for e in rec["eta"]])
for t, (acc, sel) in enumerate(zip(rec["acc"], rec["selected"])):
    print(f"round {t + 1}: global acc {acc:.3f}  "
          f"selected {sel}/{rec['num_workers']}  "
          f"up {rec['bytes_up'][t] / 2**10:.0f} KiB")
print(f"final acc {rec['final_acc']:.3f}, compression "
      f"{rec['compression_ratio']:.1f}x vs dense uplink")
