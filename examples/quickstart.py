"""Quickstart: the paper's pipeline end-to-end in ~60 lines.

Builds a small non-i.i.d. edge fleet, computes the non-i.i.d. degree
metric (Eq. 2), then runs a few M-DSL communication rounds (Algorithm 1)
and prints the selection behaviour and global-model accuracy.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.paper_cnn import paper_cnn
from repro.core import losses, mdsl, noniid
from repro.core.mdsl import MdslConfig
from repro.core.pso import PsoHyperParams
from repro.data import partition
from repro.data.synthetic import MNIST_LIKE

C, ROUNDS = 8, 4

# --- 1. a heterogeneous edge fleet: Dirichlet(alpha=0.1) label skew -------
data = partition.dirichlet_partition(
    jax.random.PRNGKey(0), C, alpha=0.1, spec=MNIST_LIKE, n_local=256)

# --- 2. the non-i.i.d. degree metric (Eq. 2) -------------------------------
eta = noniid.noniid_degree_from_labels(data.y, data.global_y,
                                       MNIST_LIKE.num_classes)
print("per-worker non-i.i.d. degree eta:",
      [f"{float(e):.2f}" for e in eta])

# --- 3. M-DSL training (Algorithm 1) ---------------------------------------
model = paper_cnn(MNIST_LIKE, width_mult=2)
loss_fn = lambda p, x, y: losses.cross_entropy_loss(model.apply(p, x), y, 10)
eval_fn = lambda p, x, y: losses.rmse_loss(model.apply(p, x), y, 10)  # Eq. 3

cfg = MdslConfig(algorithm="mdsl", tau=0.9, local_epochs=1, batch_size=64,
                 hp=PsoHyperParams(learning_rate=0.01, velocity_clip=1.0))
state = mdsl.init_state(jax.random.PRNGKey(1), model.init, C, eta)
n_params = mdsl.count_params(state.global_params)
print(f"model: {model.name}, {n_params:,} params, {C} workers")

key = jax.random.PRNGKey(2)
for t in range(ROUNDS):
    key, rkey = jax.random.split(key)
    state, m = mdsl.mdsl_round(state, data.x, data.y, data.global_x,
                               data.global_y, rkey, loss_fn=loss_fn,
                               eval_fn=eval_fn, cfg=cfg, n_params=n_params)
    acc = losses.accuracy(model.apply(state.global_params, data.test_x),
                          data.test_y)
    sel = [i for i, s in enumerate(m.mask) if s > 0]
    print(f"round {t + 1}: global acc {float(acc):.3f}  "
          f"D_g loss {float(m.global_loss):.3f}  selected {sel}")
