"""Serving example: batched prefill + decode over three architecture
families — dense (smollm), SSM (xlstm, sub-quadratic: the long_500k
family), and MoE (qwen3) — using reduced configs that execute on CPU.
The same launch/serve.py path drives full configs on a real mesh.

    PYTHONPATH=src python examples/serve_decode.py
"""
from repro.launch.serve import serve

for arch, note in [
    ("smollm-360m", "dense GQA"),
    ("xlstm-350m", "mLSTM/sLSTM recurrence -> O(1) decode state"),
    ("qwen3-moe-30b-a3b", "128-expert MoE, top-8 routing"),
]:
    print(f"\n=== {arch} ({note}) ===")
    rec = serve(arch, batch=2, prompt_len=24, gen_len=8, reduced=True)
    print(f"  sample tokens: {rec['output_sample']}")
