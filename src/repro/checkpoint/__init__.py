from repro.checkpoint.npz import (save_pytree, restore_pytree,
                                  CheckpointManager)
