"""Pytree checkpointing to .npz (no orbax offline).

Leaves are flattened to path-keyed arrays; NamedTuple / dict / list /
tuple structure is recorded in a JSON sidecar inside the archive so
`restore_pytree` rebuilds the exact container types (NamedTuples are
restored as plain dicts keyed by field name unless a `like=` template is
given — the mesh trainer always restores into a template, which also
re-applies each leaf's sharding and dtype).
"""
from __future__ import annotations

import io
import json
import os
import re
import tempfile
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any

_SEP = "/"


def _flatten_with_paths(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_elem(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_elem(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return p.name
    return re.sub(r"[^\w\.\-]", "_", str(p))


def save_pytree(path: str | os.PathLike, tree: PyTree,
                metadata: Optional[dict] = None) -> None:
    """Atomic save (write temp + rename)."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat = _flatten_with_paths(tree)
    meta = {"keys": sorted(flat), "metadata": metadata or {}}
    buf = io.BytesIO()
    np.savez(buf, __meta__=np.frombuffer(
        json.dumps(meta).encode(), dtype=np.uint8), **flat)
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        f.write(buf.getvalue())
    os.replace(tmp, path)


def restore_pytree(path: str | os.PathLike,
                   like: Optional[PyTree] = None) -> PyTree:
    """Restore. With `like`, leaves are placed into the template's
    structure (and cast to each template leaf's dtype); without it,
    returns a nested dict following the saved paths."""
    with np.load(path) as data:
        flat = {k: data[k] for k in data.files if k != "__meta__"}
    if like is not None:
        tmpl_flat = _flatten_with_paths(like)
        missing = set(tmpl_flat) - set(flat)
        extra = set(flat) - set(tmpl_flat)
        if missing or extra:
            raise ValueError(
                f"checkpoint/template mismatch: missing={sorted(missing)[:5]} "
                f"extra={sorted(extra)[:5]}")
        paths_and_leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path_elems, leaf in paths_and_leaves:
            key = _SEP.join(_path_elem(p) for p in path_elems)
            arr = flat[key]
            leaves.append(np.asarray(arr, dtype=leaf.dtype)
                          if hasattr(leaf, "dtype") else arr)
        return jax.tree_util.tree_unflatten(treedef, leaves)
    # nested-dict reconstruction
    out: dict = {}
    for key, arr in flat.items():
        node = out
        parts = key.split(_SEP)
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return out


def read_metadata(path: str | os.PathLike) -> dict:
    with np.load(path) as data:
        if "__meta__" not in data.files:
            return {}
        raw = bytes(data["__meta__"].tobytes())
    return json.loads(raw).get("metadata", {})


class CheckpointManager:
    """Step-indexed checkpoints with retention, ckpt_<step>.npz."""

    def __init__(self, directory: str | os.PathLike, max_to_keep: int = 3):
        self.dir = Path(directory)
        self.max_to_keep = max_to_keep
        self.dir.mkdir(parents=True, exist_ok=True)

    def _path(self, step: int) -> Path:
        return self.dir / f"ckpt_{step:08d}.npz"

    def all_steps(self) -> list[int]:
        return sorted(int(p.stem.split("_")[1])
                      for p in self.dir.glob("ckpt_*.npz"))

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def save(self, step: int, tree: PyTree,
             metadata: Optional[dict] = None) -> Path:
        p = self._path(step)
        save_pytree(p, tree, metadata={"step": step, **(metadata or {})})
        for s in self.all_steps()[: -self.max_to_keep]:
            self._path(s).unlink(missing_ok=True)
        return p

    def restore(self, step: Optional[int] = None,
                like: Optional[PyTree] = None) -> tuple[int, PyTree]:
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        return step, restore_pytree(self._path(step), like=like)
