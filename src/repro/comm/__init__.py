"""repro.comm — edge uplink simulation: compression, channel models,
and byte-accurate communication accounting.

The seed repo modeled the paper's §IV-C comm cost as a parameter
counter; this package puts a wire between the workers and the PS so the
comm/accuracy trade-off is an experiment axis:

  compress.py  pytree compressors (identity / top-k / int8 / int4 via
               the kernels/quant_pack fused kernel) with per-worker
               error-feedback residuals carried in the swarm state
  channel.py   uplink models (ideal / packet erasure / AWGN analog
               aggregation) + Byzantine worker attacks
  budget.py    CommConfig + per-round CommRecord in bytes on the wire

Both engines (`core/mdsl.py`, `core/swarm_dist.py`) thread a
`CommConfig` through their round functions; `launch/train.py` exposes
the flags and `benchmarks/comm_efficiency.py` sweeps the trade-off.
"""
from repro.comm.budget import (AGGREGATORS, BYZANTINE_MODES, CHANNELS,
                               COMPRESSORS, CommConfig, CommRecord,
                               degrade, dense_bytes, downlink_config,
                               host_round_bytes, leaf_payload_bytes,
                               payload_bytes, round_record, topk_count,
                               uplink_tiers)
from repro.comm.channel import (corrupt_local_updates, erasure_mask,
                                receive)
# NOTE: the compress *function* is deliberately not re-exported — it
# would shadow the `repro.comm.compress` submodule attribute.
from repro.comm.compress import (compress_with_ef, init_residual,
                                 select_residual)

__all__ = ["AGGREGATORS", "BYZANTINE_MODES", "CHANNELS", "COMPRESSORS",
           "CommConfig", "CommRecord", "compress_with_ef",
           "corrupt_local_updates", "degrade", "dense_bytes",
           "downlink_config", "erasure_mask", "host_round_bytes",
           "init_residual",
           "leaf_payload_bytes", "payload_bytes", "receive",
           "round_record", "select_residual", "topk_count",
           "uplink_tiers"]
