"""repro.comm — edge uplink simulation: compression, channel models,
and byte-accurate communication accounting.

The seed repo modeled the paper's §IV-C comm cost as a parameter
counter; this package puts a wire between the workers and the PS so the
comm/accuracy trade-off is an experiment axis:

  compress.py  pytree compressors (identity / top-k / int8 / int4 via
               the kernels/quant_pack fused kernel) with per-worker
               error-feedback residuals carried in the swarm state
  phy.py       per-worker physical layer: PhyState (Rayleigh block
               fading, pathloss, instantaneous SNR, delivery age),
               LinkModel (delivery x distortion decomposition of the
               channel enum), SNR-outage delivery
  channel.py   the Aggregate stage over the phy link (masked mean /
               robust Eq.-7 variants) + Byzantine worker attacks
  budget.py    CommConfig + per-round CommRecord: bytes on the wire,
               and SNR->rate airtime / transmit energy (rate_bps)
  straggler.py deadline-driven straggler engine: airtime-derived late
               masks, the StragglerBuffer of parked deltas, FedBuff-
               style staleness-discounted drains, quorum-gated rounds,
               and deterministic fault (churn) injection

Both engines (`core/mdsl.py`, `core/swarm_dist.py`) carry the PhyState
in their train states and thread a `CommConfig` through their round
functions; `launch/train.py` exposes the flags and
`benchmarks/comm_efficiency.py` sweeps the trade-offs (bytes, energy,
airtime).
"""
from repro.comm.budget import (AGGREGATORS, BYZANTINE_MODES, CHANNELS,
                               COMPRESSORS, FADING_MODELS, RATE_MODELS,
                               TIER_RANKS, CommConfig, CommRecord,
                               degrade, dense_bytes, downlink_config,
                               host_round_bytes, leaf_payload_bytes,
                               payload_bytes, rate_bps, round_record,
                               topk_count, uplink_tiers)
from repro.comm.channel import (corrupt_local_updates, erasure_mask,
                                receive)
# NOTE: the compress *function* is deliberately not re-exported — it
# would shadow the `repro.comm.compress` submodule attribute.
from repro.comm.compress import (compress_with_ef, init_residual,
                                 select_residual)
from repro.comm.phy import LinkModel, PhyState, delivery_mask, link_model
from repro.comm.straggler import (StragglerBuffer, StragglerStats,
                                  aggregate_and_drain, alive_mask,
                                  init_buffer, late_mask,
                                  staleness_weights)

__all__ = ["AGGREGATORS", "BYZANTINE_MODES", "CHANNELS", "COMPRESSORS",
           "CommConfig", "CommRecord", "FADING_MODELS", "LinkModel",
           "PhyState", "RATE_MODELS", "StragglerBuffer", "StragglerStats",
           "TIER_RANKS", "aggregate_and_drain", "alive_mask",
           "compress_with_ef", "corrupt_local_updates", "degrade",
           "delivery_mask", "dense_bytes", "downlink_config",
           "erasure_mask", "host_round_bytes", "init_buffer",
           "init_residual", "late_mask", "leaf_payload_bytes",
           "link_model", "payload_bytes", "rate_bps", "receive",
           "round_record", "select_residual", "staleness_weights",
           "topk_count", "uplink_tiers"]
