"""Byte-accurate communication accounting (paper §IV-C, made literal).

The seed repo counted the uplink as `n * sum_i s_i` *parameters*. This
module replaces that with bytes-on-the-wire: every compressor declares
its exact payload (values + indices + scales) and the per-round
`CommRecord` reports transmitted vs delivered bytes after the
selection × compression × channel pipeline.

Conventions (documented here, relied on by tests and benchmarks):
  * uplink payloads are counted per *transmitting* worker — a packet
    lost to erasure still consumed airtime, so `bytes_up` counts
    selected workers while `delivered` counts survivors;
  * the downlink is the uncompressed broadcast of w_t to all C workers
    (downlink compression is a ROADMAP open item);
  * quantized payloads carry one f32 scale per kernel block
    (`kernels/quant_pack` granularity), top-k payloads carry f32 value
    + int32 index pairs.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any

FLOAT_BYTES = 4   # fp32 model / value payloads
INDEX_BYTES = 4   # int32 coordinate payloads (top-k)
SCALE_BYTES = 4   # one fp32 scale per quantization block

# Elements covered by one quantization-block scale. Must match
# kernels/quant_pack (BLOCK_ROWS * 128 lanes).
QUANT_BLOCK_ELEMS = 256 * 128

COMPRESSORS = ("identity", "topk", "int8", "int4")
CHANNELS = ("ideal", "erasure", "awgn")
BYZANTINE_MODES = ("sign_flip", "gaussian")


class CommConfig(NamedTuple):
    """Static (hashable) uplink configuration, carried on the engine
    configs and closed over by the jitted round functions."""
    compressor: str = "identity"        # see COMPRESSORS
    topk_ratio: float = 0.05            # fraction of entries kept per leaf
    error_feedback: bool = True         # carry compression error residuals
    channel: str = "ideal"              # see CHANNELS
    drop_prob: float = 0.1              # erasure: P(upload lost)
    snr_db: float = 20.0                # awgn: analog-aggregation SNR
    byzantine: int = 0                  # adversarial workers (last k of C)
    byzantine_mode: str = "sign_flip"   # see BYZANTINE_MODES
    byzantine_scale: float = 1.0        # gaussian attack std

    def validate(self) -> "CommConfig":
        if self.compressor not in COMPRESSORS:
            raise ValueError(f"unknown compressor {self.compressor!r}")
        if self.channel not in CHANNELS:
            raise ValueError(f"unknown channel {self.channel!r}")
        if self.byzantine_mode not in BYZANTINE_MODES:
            raise ValueError(f"unknown byzantine mode "
                             f"{self.byzantine_mode!r}")
        if not 0.0 < self.topk_ratio <= 1.0:
            raise ValueError(f"topk_ratio must be in (0, 1], got "
                             f"{self.topk_ratio}")
        if not 0.0 <= self.drop_prob < 1.0:
            raise ValueError(f"drop_prob must be in [0, 1), got "
                             f"{self.drop_prob}")
        return self


class CommRecord(NamedTuple):
    """One round of wire accounting (all jnp scalars, jit-friendly).

    The fields are f32 telemetry: above 2^24 bytes (~16 MiB) they lose
    the last few bytes of precision. For exact numbers, do the byte
    math host-side from the counts — `int(delivered)` /
    `int(mask.sum())` times the Python-int `payload_bytes(...)`, as
    launch/train.py does for its metrics JSON."""
    bytes_up: Array            # transmitted: selected x compressed payload
    bytes_down: Array          # broadcast of w_t: C x 4n
    delivered: Array           # uploads surviving the channel
    compression_ratio: Array   # uncompressed payload / compressed payload


def topk_count(n: int, ratio: float) -> int:
    """Entries kept by top-k on an n-element leaf (>= 1)."""
    return max(1, int(n * ratio))


def _quant_blocks(n: int) -> int:
    return -(-n // QUANT_BLOCK_ELEMS)


def leaf_payload_bytes(cfg: CommConfig, n: int) -> int:
    """Exact uplink bytes for one n-element f32 leaf."""
    if cfg.compressor == "identity":
        return n * FLOAT_BYTES
    if cfg.compressor == "topk":
        return topk_count(n, cfg.topk_ratio) * (FLOAT_BYTES + INDEX_BYTES)
    if cfg.compressor == "int8":
        return n + _quant_blocks(n) * SCALE_BYTES
    if cfg.compressor == "int4":
        return -(-n // 2) + _quant_blocks(n) * SCALE_BYTES
    raise ValueError(cfg.compressor)


def payload_bytes(cfg: CommConfig, params: PyTree) -> int:
    """Per-worker uplink payload for a whole model pytree. Shapes are
    static under jit, so this is a Python int usable inside traced code."""
    return sum(leaf_payload_bytes(cfg, x.size)
               for x in jax.tree.leaves(params))


def dense_bytes(params: PyTree) -> int:
    """Uncompressed f32 payload (the seed repo's implicit unit)."""
    return sum(x.size for x in jax.tree.leaves(params)) * FLOAT_BYTES


def round_record(cfg: CommConfig, params: PyTree, num_workers: int,
                 mask: Array, mask_eff: Array) -> CommRecord:
    """Wire accounting for one round: `mask` is the Eq.-6 selection,
    `mask_eff` the post-channel survivor mask."""
    payload = payload_bytes(cfg, params)
    dense = dense_bytes(params)
    return CommRecord(
        bytes_up=mask.sum() * payload,
        bytes_down=jnp.asarray(num_workers * dense, jnp.float32),
        delivered=mask_eff.sum(),
        compression_ratio=jnp.asarray(dense / payload, jnp.float32),
    )
