"""Byte-accurate communication accounting (paper §IV-C, made literal).

The seed repo counted the uplink as `n * sum_i s_i` *parameters*. This
module replaces that with bytes-on-the-wire: every compressor declares
its exact payload (values + indices + scales) and the per-round
`CommRecord` reports transmitted vs delivered bytes after the
selection × compression × channel pipeline.

Conventions (documented here, relied on by tests and benchmarks):
  * uplink payloads are counted per *transmitting* worker — a packet
    lost to erasure still consumed airtime, so `bytes_up` counts
    selected workers while `delivered` counts survivors;
  * the downlink is the broadcast of the global update to all C
    workers, charged at the `downlink_compressor` payload (dense model
    bytes when "identity");
  * dense payloads are charged at each leaf's actual `dtype.itemsize`
    (a bf16 mesh model costs 2 bytes/param, not 4);
  * quantized payloads carry one f32 scale per kernel block
    (`kernels/quant_pack` granularity), top-k payloads carry
    native-dtype value + int32 index pairs.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any

FLOAT_BYTES = 4   # fp32 model / value payloads
INDEX_BYTES = 4   # int32 coordinate payloads (top-k)
SCALE_BYTES = 4   # one fp32 scale per quantization block

# Elements covered by one quantization-block scale. Must match
# kernels/quant_pack (BLOCK_ROWS * 128 lanes).
QUANT_BLOCK_ELEMS = 256 * 128

COMPRESSORS = ("identity", "topk", "int8", "int4")
# "composite" = packet erasure AND AWGN in one round — the delivery and
# distortion axes of comm.phy.LinkModel applied together (the legacy
# enum could only express one at a time)
CHANNELS = ("ideal", "erasure", "awgn", "composite")
BYZANTINE_MODES = ("sign_flip", "gaussian")
AGGREGATORS = ("mean", "median", "trimmed_mean")
FADING_MODELS = ("none", "rayleigh")
RATE_MODELS = ("shannon",)
TIER_RANKS = ("score", "snr")


class CommConfig(NamedTuple):
    """Static (hashable) wire configuration, carried on the engine
    configs and closed over by the jitted round functions."""
    compressor: str = "identity"        # see COMPRESSORS
    topk_ratio: float = 0.05            # fraction of entries kept per leaf
    error_feedback: bool = True         # carry compression error residuals
    channel: str = "ideal"              # see CHANNELS
    drop_prob: float = 0.1              # erasure: P(upload lost)
    snr_db: float = 20.0                # awgn: analog-aggregation SNR
    byzantine: int = 0                  # adversarial workers (last k of C)
    byzantine_mode: str = "sign_flip"   # see BYZANTINE_MODES
    byzantine_scale: float = 1.0        # gaussian attack std
    aggregator: str = "mean"            # see AGGREGATORS (Eq. 7 variants)
    trim_ratio: float = 0.1             # trimmed_mean: fraction cut per side
    downlink_compressor: str = "identity"  # PS broadcast compression
    adaptive_bits: bool = False         # per-worker wire tiers (rank-based)
    # -- physical layer (comm.phy) --------------------------------------
    fading: str = "none"                # see FADING_MODELS
    doppler_rho: float = 0.95           # Gauss-Markov round correlation
    pathloss_spread_db: float = 0.0     # static per-worker pathloss spread
    outage_snr_db: Optional[float] = None  # delivery: SNR outage cut (None off)
    rate_model: str = "shannon"         # see RATE_MODELS (SNR -> rate)
    bandwidth_hz: Optional[float] = 1e6  # uplink bandwidth per worker
    #                                      (None = no rate model: airtime/
    #                                      energy unpriced, deadlines off)
    tx_power_w: float = 0.1             # transmit power (energy accounting)
    coding_gap_db: float = 3.0          # practical-coding gap to capacity
    # -- adaptive tiers (widened: N tiers, score- or SNR-ranked) --------
    num_tiers: int = 2                  # adaptive_bits: wire tier count
    tier_rank: str = "score"            # see TIER_RANKS (Eq.-5 | inst. SNR)
    # -- straggler / deadline engine (comm.straggler) -------------------
    round_deadline_s: Optional[float] = None  # uplink airtime budget per
    #                                    round; an upload whose airtime
    #                                    exceeds it is late -> buffered
    #                                    (None = every upload on time)
    staleness_gamma: float = 1.0        # drain discount 1/(1+age)^gamma
    quorum: int = 0                     # min deltas (fresh + drained) to
    #                                    apply an aggregate (0 = no gate)
    # -- fault injection (deterministic worker churn) -------------------
    fault_prob: float = 0.0             # P(worker starts an outage /round)
    fault_rounds: int = 1               # outage length in rounds
    fault_seed: int = 0                 # schedule stream (static, keyed
    #                                    off the round index like POP_SALT)

    def validate(self) -> "CommConfig":
        if self.compressor not in COMPRESSORS:
            raise ValueError(f"unknown compressor {self.compressor!r}")
        if self.downlink_compressor not in COMPRESSORS:
            raise ValueError(f"unknown downlink compressor "
                             f"{self.downlink_compressor!r}")
        if self.channel not in CHANNELS:
            raise ValueError(f"unknown channel {self.channel!r}")
        if self.aggregator not in AGGREGATORS:
            raise ValueError(f"unknown aggregator {self.aggregator!r}")
        if self.byzantine_mode not in BYZANTINE_MODES:
            raise ValueError(f"unknown byzantine mode "
                             f"{self.byzantine_mode!r}")
        if not 0.0 < self.topk_ratio <= 1.0:
            raise ValueError(f"topk_ratio must be in (0, 1], got "
                             f"{self.topk_ratio}")
        if not 0.0 <= self.drop_prob < 1.0:
            raise ValueError(f"drop_prob must be in [0, 1), got "
                             f"{self.drop_prob}")
        if not 0.0 <= self.trim_ratio < 0.5:
            raise ValueError(f"trim_ratio must be in [0, 0.5), got "
                             f"{self.trim_ratio}")
        if self.fading not in FADING_MODELS:
            raise ValueError(f"unknown fading model {self.fading!r}")
        if self.rate_model not in RATE_MODELS:
            raise ValueError(f"unknown rate model {self.rate_model!r}")
        if self.tier_rank not in TIER_RANKS:
            raise ValueError(f"unknown tier rank {self.tier_rank!r}")
        if not 0.0 <= self.doppler_rho <= 1.0:
            raise ValueError(f"doppler_rho must be in [0, 1], got "
                             f"{self.doppler_rho}")
        if self.pathloss_spread_db < 0.0:
            raise ValueError(f"pathloss_spread_db must be >= 0, got "
                             f"{self.pathloss_spread_db}")
        if self.bandwidth_hz is not None and self.bandwidth_hz <= 0.0:
            raise ValueError(f"bandwidth_hz must be > 0 (or None to "
                             f"disable the rate model), got "
                             f"{self.bandwidth_hz}")
        if self.tx_power_w <= 0.0:
            raise ValueError(f"tx_power_w must be > 0, got "
                             f"{self.tx_power_w}")
        if self.coding_gap_db < 0.0:
            raise ValueError(f"coding_gap_db must be >= 0, got "
                             f"{self.coding_gap_db}")
        if self.num_tiers < 2:
            raise ValueError(f"num_tiers must be >= 2, got {self.num_tiers}")
        if (self.tier_rank == "snr" and self.fading == "none"
                and self.pathloss_spread_db == 0.0):
            raise ValueError(
                "tier_rank='snr' needs per-worker SNRs: enable "
                "fading='rayleigh' or a pathloss_spread_db > 0 — with a "
                "uniform SNR the ranking is arbitrary")
        if (self.outage_snr_db is not None and self.fading == "none"
                and self.pathloss_spread_db == 0.0):
            raise ValueError(
                "outage_snr_db needs per-worker SNR dynamics "
                "(fading='rayleigh' or pathloss_spread_db > 0) — with "
                "one static fleet-wide SNR the outage is a degenerate "
                "all-or-nothing blackout")
        if self.round_deadline_s is not None and self.round_deadline_s <= 0.0:
            raise ValueError(f"round_deadline_s must be > 0 (or None to "
                             f"disable deadlines), got "
                             f"{self.round_deadline_s}")
        if self.round_deadline_s is not None and self.bandwidth_hz is None:
            # mirrors the outage-needs-per-worker-SNR cross-check: a
            # deadline is only meaningful against an airtime, and airtime
            # needs the SNR -> rate model
            raise ValueError(
                "round_deadline_s needs a rate model to derive airtimes "
                "(payload_bytes / rate_bps) — set bandwidth_hz")
        if self.staleness_gamma < 0.0:
            raise ValueError(f"staleness_gamma must be >= 0, got "
                             f"{self.staleness_gamma}")
        if self.quorum < 0:
            raise ValueError(f"quorum must be >= 0, got {self.quorum}")
        if self.quorum > 0 and self.round_deadline_s is None:
            raise ValueError(
                "quorum gating rides the straggler engine — set "
                "round_deadline_s to enable it")
        if not 0.0 <= self.fault_prob < 1.0:
            raise ValueError(f"fault_prob must be in [0, 1), got "
                             f"{self.fault_prob}")
        if self.fault_rounds < 1:
            raise ValueError(f"fault_rounds must be >= 1, got "
                             f"{self.fault_rounds}")
        return self


class CommRecord(NamedTuple):
    """One round of wire accounting (all jnp scalars, jit-friendly).

    The fields are f32 telemetry: above 2^24 bytes (~16 MiB) they lose
    the last few bytes of precision. For exact numbers, do the byte
    math host-side from the counts — `int(delivered)` /
    `int(mask.sum())` times the Python-int `payload_bytes(...)`, as
    launch/train.py does for its metrics JSON."""
    bytes_up: Array            # transmitted: selected x compressed payload
    bytes_down: Array          # broadcast: C x downlink payload
    delivered: Array           # uploads surviving the channel
    compression_ratio: Array   # uncompressed payload / mean uplink payload
    airtime_s: Array           # uplink airtime: sum_i s_i bits_i / rate_i
    energy_j: Array            # transmit energy: tx_power_w * airtime
    mean_snr_db: Array         # fleet-mean instantaneous received SNR


def topk_count(n: int, ratio: float) -> int:
    """Entries kept by top-k on an n-element leaf (>= 1)."""
    return max(1, int(n * ratio))


def _quant_blocks(n: int) -> int:
    return -(-n // QUANT_BLOCK_ELEMS)


def leaf_payload_bytes(cfg: CommConfig, n: int,
                       itemsize: int = FLOAT_BYTES) -> int:
    """Exact uplink bytes for one n-element leaf of `itemsize`-byte
    dtype. Quantized payloads are dtype-independent (b bits/entry plus
    scales); dense and top-k values ship in the native dtype."""
    if cfg.compressor == "identity":
        return n * itemsize
    if cfg.compressor == "topk":
        return topk_count(n, cfg.topk_ratio) * (itemsize + INDEX_BYTES)
    if cfg.compressor == "int8":
        return n + _quant_blocks(n) * SCALE_BYTES
    if cfg.compressor == "int4":
        return -(-n // 2) + _quant_blocks(n) * SCALE_BYTES
    raise ValueError(cfg.compressor)


def payload_bytes(cfg: CommConfig, params: PyTree) -> int:
    """Per-worker uplink payload for a whole model pytree. Shapes are
    static under jit, so this is a Python int usable inside traced code."""
    return sum(leaf_payload_bytes(cfg, x.size, jnp.dtype(x.dtype).itemsize)
               for x in jax.tree.leaves(params))


def dense_bytes(params: PyTree) -> int:
    """Uncompressed payload at each leaf's actual dtype width (bf16
    leaves are charged 2 bytes/param; the seed repo assumed f32)."""
    return sum(x.size * jnp.dtype(x.dtype).itemsize
               for x in jax.tree.leaves(params))


def downlink_config(cfg: CommConfig) -> CommConfig:
    """The PS-side broadcast wire config: the downlink compressor with
    PS error feedback always on (one residual, telescoping the
    quantized global deltas — rounds.downlink)."""
    return cfg._replace(compressor=cfg.downlink_compressor,
                        error_feedback=True)


def degrade(cfg: CommConfig) -> CommConfig:
    """One wire tier down in bits: identity -> int8 -> int4; top-k
    halves its keep ratio. int4 is already the floor."""
    if cfg.compressor == "identity":
        return cfg._replace(compressor="int8")
    if cfg.compressor == "int8":
        return cfg._replace(compressor="int4")
    if cfg.compressor == "topk":
        return cfg._replace(topk_ratio=cfg.topk_ratio / 2.0)
    return cfg


def uplink_tiers(cfg: CommConfig) -> tuple[CommConfig, ...]:
    """Per-worker CommConfig resolution (adaptive bit allocation): with
    `adaptive_bits` set, the degradation chain of up to `num_tiers`
    configs the PS assigns down the worker ranking (Eq.-5 score or
    instantaneous SNR, `tier_rank`); the chain stops early at the int4
    floor. Tier 0 is the base config (best-ranked workers)."""
    if not cfg.adaptive_bits:
        return (cfg,)
    tiers = [cfg]
    while len(tiers) < cfg.num_tiers:
        nxt = degrade(tiers[-1])
        if nxt == tiers[-1]:
            break
        tiers.append(nxt)
    return tuple(tiers)


def rate_bps(cfg: CommConfig, snr_db: Array) -> Array:
    """SNR -> achievable uplink rate (bits/s): Shannon capacity backed
    off by a practical-coding gap,

        R = B log2(1 + 10^((snr_db - gap_db) / 10)).

    This is what converts payload bytes into airtime and energy."""
    if cfg.bandwidth_hz is None:
        raise ValueError("rate_bps: no rate model (bandwidth_hz is None)")
    eff_snr = 10.0 ** ((snr_db - cfg.coding_gap_db) / 10.0)
    return cfg.bandwidth_hz * jnp.log2(1.0 + eff_snr)


def worker_payload_bytes(cfg: CommConfig, params: PyTree,
                         num_workers: int,
                         tier_idx: Array = None) -> Array:
    """(C,) f32 uplink payload bytes per worker, resolving per-worker
    wire tiers (`tier_idx` indexes `uplink_tiers(cfg)`; None = the fleet
    shares one wire config). Payload sizes are static Python ints, so
    this is jit-safe."""
    tiers = uplink_tiers(cfg)
    payloads = [payload_bytes(t, params) for t in tiers]
    if tier_idx is None or len(tiers) == 1:
        return jnp.full((num_workers,), payloads[0], jnp.float32)
    return sum((tier_idx == t).astype(jnp.float32) * p
               for t, p in enumerate(payloads))


def worker_airtime_s(cfg: CommConfig, worker_bytes: Array,
                     snr_db: Array) -> Array:
    """(C,) per-upload airtime: bits on the wire over the achievable
    rate at each worker's received SNR. The straggler engine compares
    this against `round_deadline_s` to derive deadline misses."""
    return 8.0 * worker_bytes / rate_bps(cfg, snr_db)


def host_round_bytes(cfg: CommConfig, *, selected, bytes_up_jit,
                     payload_up: int, payload_down: int,
                     num_workers: int) -> tuple[float, int]:
    """Exact host-side (bytes_up, bytes_down) for one round's metrics
    record. The in-jit CommRecord is f32 telemetry that drifts above
    2^24 bytes (~16 MiB), so the uplink is recomputed from exact ints —
    selected transmitters x the Python-int payload — except under
    adaptive tiers, where workers mix per-tier payloads and the in-jit
    accounting is the only per-assignment truth. Used by the experiment
    runner for both the paper and mesh drivers (previously duplicated in
    each)."""
    up = (float(bytes_up_jit) if cfg.adaptive_bits
          else int(selected) * payload_up)
    return up, num_workers * payload_down


def round_record(cfg: CommConfig, params: PyTree, num_workers: int,
                 mask: Array, mask_eff: Array, tier_idx: Array = None,
                 snr_db: Array = None) -> CommRecord:
    """Wire accounting for one round: `mask` is the Eq.-6 selection,
    `mask_eff` the post-channel survivor mask, `tier_idx` the (C,)
    per-worker wire-tier index into `uplink_tiers(cfg)` (None when the
    fleet shares one wire config), `snr_db` the (C,) instantaneous
    received SNRs from the PhyState (None = the shared link budget
    `cfg.snr_db` — airtime/energy still price out, just uniformly)."""
    tiers = uplink_tiers(cfg)
    dense = dense_bytes(params)
    payloads = [payload_bytes(t, params) for t in tiers]
    worker_bytes = worker_payload_bytes(cfg, params, num_workers,
                                        tier_idx=tier_idx)
    if tier_idx is None or len(tiers) == 1:
        bytes_up = mask.sum() * payloads[0]
        mean_payload = payloads[0]
    else:
        on_tier = [(tier_idx == t).astype(jnp.float32)
                   for t in range(len(tiers))]
        bytes_up = sum((mask * on_t).sum() * p
                       for on_t, p in zip(on_tier, payloads))
        mean_payload = sum(p * on_t.sum()
                           for on_t, p in zip(on_tier, payloads)
                           ) / num_workers
    bytes_down = num_workers * payload_bytes(downlink_config(cfg), params)
    # SNR -> rate -> airtime/energy: every transmitting (selected) worker
    # occupies the channel for bits/rate seconds, lost packets included —
    # a drop wastes the airtime it consumed (same convention as bytes_up)
    snr = (snr_db if snr_db is not None
           else jnp.full(mask.shape, cfg.snr_db, jnp.float32))
    if cfg.bandwidth_hz is None:
        airtime = jnp.zeros((), jnp.float32)  # no rate model: unpriced
    else:
        airtime = (mask * worker_airtime_s(cfg, worker_bytes, snr)).sum()
    return CommRecord(
        bytes_up=bytes_up,
        bytes_down=jnp.asarray(bytes_down, jnp.float32),
        delivered=mask_eff.sum(),
        compression_ratio=jnp.asarray(dense / mean_payload, jnp.float32),
        airtime_s=airtime.astype(jnp.float32),
        energy_j=(cfg.tx_power_w * airtime).astype(jnp.float32),
        mean_snr_db=snr.mean().astype(jnp.float32),
    )
