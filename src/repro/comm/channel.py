"""Uplink channel + Eq.-7 Aggregate stage, as a thin layer over the
per-worker physical layer in `comm/phy.py`.

The legacy enum configs are degenerate `phy.LinkModel` resolutions of
one composable path (delivery x distortion; see phy.link_model):

  ideal      lossless digital uplink (no delivery loss, no distortion)
  erasure    delivery: each selected upload lost i.i.d. with `drop_prob`
             (packet erasure / straggler timeout). A lost upload falls
             out of Eq. 7's masked mean — the denominator shrinks to the
             survivors and an all-lost round leaves w_t unchanged.
  awgn       distortion: AWGN at `snr_db`. With a fleet-shared SNR this
             is over-the-air analog aggregation (arXiv:2510.18152) —
             noise on the superposed sum before the 1/|S| normalization.
             With per-worker SNRs (Rayleigh fading / pathloss spread,
             `comm.phy`) it is per-upload digital decode noise at each
             worker's OWN instantaneous SNR.
  composite  delivery AND distortion in one round — drop_prob and
             snr_db both apply (the enum could not compose them).

An `outage_snr_db` threshold adds SNR-outage delivery loss on top of
any of these (a worker faded below the threshold cannot close the
link), and `fading="rayleigh"` evolves the per-worker channel state
round to round (`rounds.wire_round` threads the PhyState).

Byzantine workers (CB-DSL, arXiv:2208.05578) are modeled as faulty
nodes: the *last* `byzantine` of the C workers compute adversarial
local updates (sign-flipped, or pure Gaussian noise) that corrupt their
own round params. Their D_g scores therefore reflect the corruption,
which is what lets Eq. 6's function-value selection reject them — the
CB-DSL robustness mechanism — while FedAvg averages them in every round.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.comm import compress as comm_compress
from repro.comm import phy as comm_phy
from repro.comm.budget import CommConfig
from repro.kernels.wire_agg import wire_aggregate

Array = jax.Array
PyTree = Any


def corrupt_local_updates(cfg: CommConfig, prev_params: PyTree,
                          new_params: PyTree, key: Array) -> PyTree:
    """Replace the last `cfg.byzantine` workers' local updates with the
    attack. All leaves carry a leading worker dim C."""
    if cfg.byzantine <= 0:
        return new_params
    leaves, treedef = jax.tree.flatten(new_params)
    prev_leaves = jax.tree.leaves(prev_params)
    C = leaves[0].shape[0]
    byz = (jnp.arange(C) >= C - cfg.byzantine)

    out = []
    for i, (new, prev) in enumerate(zip(leaves, prev_leaves)):
        if cfg.byzantine_mode == "sign_flip":
            attacked = 2.0 * prev - new          # delta -> -delta
        else:                                    # gaussian
            noise = cfg.byzantine_scale * jax.random.normal(
                jax.random.fold_in(key, i), new.shape, jnp.float32)
            attacked = prev + noise.astype(new.dtype)
        m = byz.reshape((-1,) + (1,) * (new.ndim - 1))
        out.append(jnp.where(m, attacked.astype(new.dtype), new))
    return jax.tree.unflatten(treedef, out)


def erasure_mask(cfg: CommConfig, mask: Array, key: Array,
                 snr_db: Optional[Array] = None) -> Array:
    """Post-channel survivor mask (compat shim over phy.delivery_mask:
    packet erasure composed with SNR outage)."""
    return comm_phy.delivery_mask(cfg, mask, key, snr_db=snr_db)


def receive(cfg: CommConfig, global_params: PyTree, wire_deltas: PyTree,
            mask: Array, key: Array, snr_db: Optional[Array] = None
            ) -> tuple[PyTree, Array]:
    """Uplink channel + Eq.-7 Aggregate stage: push the selected
    workers' wire deltas through the link (delivery then distortion,
    phy.LinkModel) and fold the aggregate (cfg.aggregator: masked mean,
    coordinate-wise median, or trimmed mean) into the global model.

    wire_deltas: pytree with leading worker dim C (decoded payloads from
    `compress`); mask: (C,) Eq.-6 selection; snr_db: (C,) instantaneous
    received SNRs from the PhyState (None = fleet-shared cfg.snr_db).
    Returns (w_{t+1}, mask_eff) where mask_eff marks the uploads that
    actually arrived.
    """
    link = comm_phy.link_model(cfg)
    ekey, nkey = jax.random.split(key)
    mask_eff = comm_phy.delivery_mask(cfg, mask, ekey, snr_db=snr_db)
    if cfg.aggregator != "mean":
        return _robust_receive(cfg, link, global_params, wire_deltas,
                               mask_eff, nkey, snr_db), mask_eff
    denom = jnp.maximum(mask_eff.sum(), 1.0)

    g_leaves, treedef = jax.tree.flatten(global_params)
    d_leaves = jax.tree.leaves(wire_deltas)
    out = []
    for i, (g, d) in enumerate(zip(g_leaves, d_leaves)):
        d = d.astype(jnp.float32)
        m = mask_eff.reshape((-1,) + (1,) * (d.ndim - 1))
        if link.awgn and link.per_worker and snr_db is not None:
            # per-upload digital decode noise at each worker's own SNR
            sigma = comm_phy.noise_sigma_per_worker(d, snr_db)
            d = d + sigma * jax.random.normal(jax.random.fold_in(nkey, i),
                                              d.shape, jnp.float32)
        s = (m * d).sum(axis=0)
        if link.awgn and not (link.per_worker and snr_db is not None):
            # AWGN on the superposed analog signal, before the 1/|S|
            # normalization; sigma from the per-round signal power.
            sigma = comm_phy.noise_sigma_superposed(cfg, s)
            s = s + sigma * jax.random.normal(jax.random.fold_in(nkey, i),
                                              s.shape, jnp.float32)
        out.append((g + s / denom).astype(g.dtype))
    return jax.tree.unflatten(treedef, out), mask_eff


def receive_packed(cfg: CommConfig, global_params: PyTree,
                   wire: "comm_compress.PackedWire", mask: Array,
                   key: Array, snr_db: Optional[Array] = None,
                   weights: Optional[Array] = None
                   ) -> tuple[PyTree, Array]:
    """Fused-wire sibling of `receive`: the PS decodes C *packed*
    payloads (stacked PackedWire from `compress_with_ef_packed`)
    straight into the Eq.-7 aggregate via `kernels.wire_agg`, never
    materializing the C dense reconstructions.

    Only reachable for `compress.packed_wire_eligible` configs (no AWGN
    value distortion); delivery — packet erasure composed with SNR
    outage — consumes the same ekey split as `receive`, so survivor
    masks and therefore aggregates are bit-identical to the legacy
    dense route (asserted in tests/test_wire_kernels.py)."""
    ekey, _nkey = jax.random.split(key)   # same split discipline as receive
    mask_eff = comm_phy.delivery_mask(cfg, mask, ekey, snr_db=snr_db)
    bits = comm_compress.quant_bits(cfg)
    g_leaves, treedef = jax.tree.flatten(global_params)
    out = []
    for g, p, s in zip(g_leaves, wire.packed, wire.scales):
        agg = wire_aggregate(p, s, mask_eff, shape=g.shape, bits=bits,
                             aggregator=cfg.aggregator,
                             trim_ratio=cfg.trim_ratio, weights=weights)
        out.append((g + agg).astype(g.dtype))
    return jax.tree.unflatten(treedef, out), mask_eff


def _robust_receive(cfg: CommConfig, link: comm_phy.LinkModel,
                    global_params: PyTree, wire_deltas: PyTree,
                    mask_eff: Array, nkey: Array,
                    snr_db: Optional[Array]) -> PyTree:
    """Byzantine-robust Eq.-7 variants (CB-DSL, arXiv:2208.05578):
    coordinate-wise median / trimmed mean over the delivered deltas.

    Robust statistics need the individual uploads at the PS, so AWGN
    here is per-upload digital decode noise, not the analog
    superposition of the mean path — at each worker's own SNR when the
    phy differentiates them, at the shared `snr_db` otherwise.
    Non-delivered workers are masked to +inf and sorted to the top; the
    traced survivor count k picks the order statistics, so erasure (and
    SNR outage) composes with robustness.
    """
    k = mask_eff.sum().astype(jnp.int32)
    g_leaves, treedef = jax.tree.flatten(global_params)
    d_leaves = jax.tree.leaves(wire_deltas)
    out = []
    for i, (g, d) in enumerate(zip(g_leaves, d_leaves)):
        C = d.shape[0]
        d = d.astype(jnp.float32)
        m = mask_eff.reshape((-1,) + (1,) * (d.ndim - 1))
        if link.awgn:
            if link.per_worker and snr_db is not None:
                sigma = comm_phy.noise_sigma_per_worker(d, snr_db)
            else:
                n_el = jnp.maximum(mask_eff.sum(), 1.0) * (d.size // C)
                sig_rms = jnp.sqrt((m * d * d).sum() / n_el)
                sigma = sig_rms * (10.0 ** (-cfg.snr_db / 20.0))
            d = d + sigma * jax.random.normal(jax.random.fold_in(nkey, i),
                                              d.shape, jnp.float32)
        svals = jnp.sort(jnp.where(m > 0, d, jnp.inf), axis=0)
        if cfg.aggregator == "median":
            lo = jnp.maximum(k - 1, 0) // 2
            hi = jnp.maximum(k - 1, 0) - lo
            agg = 0.5 * (jax.lax.dynamic_index_in_dim(svals, lo, 0, False)
                         + jax.lax.dynamic_index_in_dim(svals, hi, 0,
                                                        False))
        else:  # trimmed_mean: cut t of the k survivors from each end
            t = (cfg.trim_ratio * k.astype(jnp.float32)).astype(jnp.int32)
            t = jnp.minimum(t, jnp.maximum(k - 1, 0) // 2)
            idx = jnp.arange(C).reshape((-1,) + (1,) * (d.ndim - 1))
            keep = (idx >= t) & (idx < k - t)
            cnt = jnp.maximum((k - 2 * t).astype(jnp.float32), 1.0)
            agg = jnp.where(keep, svals, 0.0).sum(axis=0) / cnt
        agg = jnp.where(k > 0, agg, 0.0)  # all-lost round: w_t unchanged
        out.append((g + agg).astype(g.dtype))
    return jax.tree.unflatten(treedef, out)
