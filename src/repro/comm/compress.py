"""Pytree-aware, jit-able uplink compressors with error feedback.

A compressor maps one worker's round delta (a parameter pytree) to the
dense reconstruction the parameter server decodes from the wire — the
simulation trains on exactly what a byte-accurate receiver would see,
while `budget.payload_bytes` charges the matching wire cost:

  identity  the delta itself                           (4n bytes)
  topk      k = max(1, floor(ratio*n)) largest-|.| entries per leaf,
            as (value, index) pairs                    (8k bytes)
  int8/int4 block-scaled stochastic quantization via the fused
            kernels/quant_pack kernel (ref path on CPU)
                                                       (bn/8 + scales)

Error feedback (Seide et al.; SNIPPETS.md idiom): each worker carries a
residual e_i of everything its past uploads dropped; round t compresses
delta_t + e_t and keeps the new error. The residual telescopes — the sum
of decoded uploads tracks the sum of true deltas to within one
compression error — which is what lets compressed M-DSL converge
(verified in tests/test_comm.py). Residuals live in the swarm state and
are only advanced for workers whose upload was actually attempted
(selected by Eq. 6); a deselected worker's residual is untouched.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.comm.budget import CommConfig, topk_count
from repro.kernels.quant_pack import quant_dequant, quantize_pack_ef

Array = jax.Array
PyTree = Any

_QUANT_BITS = {"int8": 8, "int4": 4}


def _topk_leaf(x: Array, k: int) -> Array:
    """Dense decode of a top-k sparsified leaf: the k largest-|.| entries
    survive, everything else is zero."""
    flat = x.reshape(-1).astype(jnp.float32)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    wire = jnp.zeros_like(flat).at[idx].set(flat[idx])
    return wire.reshape(x.shape).astype(x.dtype)


def compress(cfg: CommConfig, tree: PyTree, key: Array) -> PyTree:
    """One worker's uplink: pytree -> decoded-payload pytree. `key`
    drives stochastic rounding (per-leaf seeds are folded in)."""
    if cfg.compressor == "identity":
        return tree

    leaves, treedef = jax.tree.flatten(tree)
    if cfg.compressor == "topk":
        out = [_topk_leaf(x, topk_count(x.size, cfg.topk_ratio))
               for x in leaves]
    else:
        bits = 8 if cfg.compressor == "int8" else 4
        out = []
        for i, x in enumerate(leaves):
            seed = jax.random.randint(jax.random.fold_in(key, i), (),
                                      0, jnp.iinfo(jnp.int32).max)
            out.append(quant_dequant(x.astype(jnp.float32), seed,
                                     bits=bits).astype(x.dtype))
    return jax.tree.unflatten(treedef, out)


def compress_with_ef(cfg: CommConfig, delta: PyTree, residual: PyTree,
                     key: Array) -> tuple[PyTree, PyTree]:
    """Error-feedback step for one worker: compress delta + residual,
    return (wire, new_residual). With error_feedback off the residual
    stays zero and the compression error is simply dropped."""
    if cfg.error_feedback:
        acc = jax.tree.map(lambda d, r: d + r.astype(d.dtype), delta,
                           residual)
    else:
        acc = delta
    wire = compress(cfg, acc, key)
    if cfg.error_feedback:
        new_residual = jax.tree.map(lambda a, w: (a - w).astype(jnp.float32),
                                    acc, wire)
    else:
        new_residual = jax.tree.map(jnp.zeros_like, residual)
    return wire, new_residual


class PackedWire(NamedTuple):
    """One worker's quantized uplink in actual wire format: per-leaf
    packed integer planes + per-block f32 scales, tuples aligned with
    the delta treedef's flattened leaves. A pytree — the engines vmap it
    over workers, stacking each plane to (C, ...) for the PS-side fused
    decode+aggregate (`channel.receive_packed`)."""
    packed: tuple
    scales: tuple


def quant_bits(cfg: CommConfig) -> Optional[int]:
    """Wire bit width of a quantizing compressor (None otherwise)."""
    return _QUANT_BITS.get(cfg.compressor)


def packed_wire_eligible(cfg: CommConfig, tree: PyTree) -> bool:
    """True when the fused wire-format route applies: quantized uplink
    (int8/int4) at one fleet-wide tier, a link that never perturbs
    payload *values* (no AWGN — erasure/outage only gate delivery, which
    the packed route handles via the mask), and f32 leaves (the fused
    kernels produce f32 residuals/aggregates; mixed-precision models
    keep the dense route's per-leaf astype semantics). Static under jit:
    depends only on the config and leaf dtypes.

    The straggler engine (round_deadline_s) also forces the dense route:
    late uploads must be parked as dense decoded deltas in the per-worker
    buffer, so the PS needs the individual reconstructions the fused
    aggregate never materializes (docs/async.md)."""
    from repro.comm.phy import link_model
    if quant_bits(cfg) is None or cfg.adaptive_bits:
        return False
    if cfg.round_deadline_s is not None:
        return False
    if link_model(cfg).awgn:
        return False
    return all(jnp.dtype(x.dtype) == jnp.float32
               for x in jax.tree.leaves(tree))


def compress_with_ef_packed(cfg: CommConfig, delta: PyTree, residual: PyTree,
                            key: Array) -> tuple[PackedWire, PyTree]:
    """Fused-wire sibling of `compress_with_ef` for one worker:
    quantize + pack + error-feedback update in one kernel pass per leaf
    (`kernels.quant_pack.quantize_pack_ef`), returning the payload in
    wire format instead of the dense decode. Per-leaf seeds, packed
    bits, and scales are bit-identical to the legacy compress ->
    dequant chain (both see the same delta + residual values — in
    wire_round delta is a stage input, so no caller op can FMA-fuse
    into one route's EF accumulate only); the new residual agrees up
    to XLA's FMA contraction of the final subtract, which the legacy
    route performs at leaf shape and the fused pass at the padded
    block shape (tests/test_wire_kernels.py pins both).

    Only called for `packed_wire_eligible` configs. Returns
    (PackedWire, new_residual)."""
    bits = quant_bits(cfg)
    leaves, treedef = jax.tree.flatten(delta)
    res_leaves = jax.tree.leaves(residual)
    packed, scales, new_res = [], [], []
    for i, (x, r) in enumerate(zip(leaves, res_leaves)):
        # same per-leaf seed stream as compress(): fold_in(key, leaf i)
        seed = jax.random.randint(jax.random.fold_in(key, i), (),
                                  0, jnp.iinfo(jnp.int32).max)
        r_in = r if cfg.error_feedback else jnp.zeros_like(r)
        p, s, res = quantize_pack_ef(x, r_in, seed, bits=bits)
        packed.append(p)
        scales.append(s)
        new_res.append(res if cfg.error_feedback else jnp.zeros_like(res))
    return (PackedWire(tuple(packed), tuple(scales)),
            jax.tree.unflatten(treedef, new_res))


def init_residual(params: PyTree) -> PyTree:
    """Zero error-feedback state shaped like one worker's model (f32)."""
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)


def select_residual(mask: Array, new_residual: PyTree,
                    old_residual: PyTree) -> PyTree:
    """Advance residuals only for workers whose upload was attempted.
    All leaves carry a leading worker dim; mask: (C,)."""
    def leaf(n, o):
        m = mask.reshape((-1,) + (1,) * (n.ndim - 1))
        return jnp.where(m > 0, n, o)

    return jax.tree.map(leaf, new_residual, old_residual)
