"""repro.comm.phy — the per-worker physical layer under the uplink.

The seed channel was one `if cfg.channel == ...` enum with a single
scalar `snr_db` shared by every worker. This module gives the wire a
real PHY with per-worker, round-to-round state:

  PhyState     per-worker complex fading gain (h_re/h_im), static
               pathloss, the instantaneous received SNR derived from
               them, and an age counter (rounds since the worker's last
               delivered upload — the seed slot for async/stale-round
               aggregation).
  evolve       Rayleigh block fading as a Gauss-Markov process:
                   h_{t+1} = rho h_t + sqrt(1 - rho^2) CN(0, 1)
               (`doppler_rho` = round-to-round correlation; rho=1 is a
               static channel, rho=0 draws i.i.d. per round). Workers
               start at unit gain, so E|h_t|^2 = 1 for every t — the
               fading is unbiased from round 0, not just in the
               stationary limit.
  LinkModel    the old channel enum decomposed into orthogonal effects:
                 delivery    packet erasure (drop_prob) AND/OR an SNR
                             outage threshold (outage_snr_db)
                 distortion  AWGN at the received SNR — the legacy
                             analog superposition when the fleet shares
                             one SNR, per-upload digital decode noise
                             when SNRs differ per worker
               so ideal / erasure / awgn / composite are degenerate
               configurations of ONE path instead of three branches
               (Byzantine corruption stays in `channel.py`: it happens
               at the workers, before the wire).

The SNR→achievable-rate model (`budget.rate_bps`: Shannon capacity with
a practical-coding gap) converts each worker's payload bytes into
airtime and transmit energy; `budget.round_record` charges them next to
bytes_up so accuracy-vs-energy is an experiment axis
(benchmarks/comm_efficiency.py).

Key discipline (golden-pinned): the legacy ideal/erasure/awgn configs
consume randomness exactly as before — delivery uses the same ekey
bernoulli, distortion the same per-leaf fold_in(nkey, i) draws, and the
fading evolution lives on its own fold_in(wkey, PHY_SALT) stream — so
`fading="none"` runs are bit-identical through this seam.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.comm.budget import CommConfig, rate_bps  # noqa: F401 (re-export)

Array = jax.Array

PHY_SALT = 0xF0   # fading evolution key = fold_in(wkey, PHY_SALT): keeps
#                   the engines' legacy key-split structure (and goldens)
#                   unchanged

_GAIN_FLOOR = 1e-12   # |h|^2 floor before the dB conversion (deep fade)


class PhyState(NamedTuple):
    """Per-worker physical-layer state, one slot per worker (leading C).

    A jit/vmap/spmd-safe pytree carried in the engines' train states and
    threaded through `rounds.wire_round` (sharded over the worker axes
    on the mesh path, next to losses/eta)."""
    h_re: Array          # (C,) fading gain, real part
    h_im: Array          # (C,) fading gain, imag part
    pathloss_db: Array   # (C,) static per-worker pathloss (>= 0 dB)
    snr_db: Array        # (C,) instantaneous received SNR this round
    age: Array           # (C,) int32 rounds since last delivered upload


def pathloss_profile(cfg: CommConfig, num_workers: int) -> Array:
    """Static per-worker pathloss: workers spread evenly over
    [0, pathloss_spread_db] dB of extra attenuation (worker 0 closest
    to the PS). Deterministic so specs stay reproducible without a key."""
    if num_workers == 1:
        return jnp.zeros((1,), jnp.float32)
    return jnp.linspace(0.0, cfg.pathloss_spread_db, num_workers,
                        dtype=jnp.float32)


def instantaneous_snr_db(cfg: CommConfig, h_re: Array, h_im: Array,
                         pathloss_db: Array) -> Array:
    """Received SNR per worker: the link budget `snr_db` minus pathloss
    plus the fading gain |h|^2 in dB."""
    gain2 = jnp.maximum(h_re * h_re + h_im * h_im, _GAIN_FLOOR)
    return (cfg.snr_db - pathloss_db
            + 10.0 * jnp.log10(gain2)).astype(jnp.float32)


def init_state(cfg: CommConfig, num_workers: int) -> PhyState:
    """Unit-gain start (|h_0| = 1, zero phase) for every fading model:
    E|h_t|^2 = rho^{2t} |h_0|^2 + (1 - rho^{2t}) = 1 exactly, so the
    Gauss-Markov recursion is unbiased from the first round and no init
    key is needed."""
    ones = jnp.ones((num_workers,), jnp.float32)
    zeros = jnp.zeros((num_workers,), jnp.float32)
    pl = pathloss_profile(cfg, num_workers)
    return PhyState(h_re=ones, h_im=zeros, pathloss_db=pl,
                    snr_db=instantaneous_snr_db(cfg, ones, zeros, pl),
                    age=jnp.zeros((num_workers,), jnp.int32))


def evolve(cfg: CommConfig, phy: PhyState, key: Array) -> PhyState:
    """One round of Rayleigh block fading (Gauss-Markov / Jakes AR-1):

        h_{t+1} = rho h_t + sqrt(1 - rho^2) CN(0, 1)

    each complex component N(0, 1/2) so the innovation has unit power.
    `fading="none"` is the identity (no randomness consumed)."""
    if cfg.fading == "none":
        return phy
    rho = cfg.doppler_rho
    innov = jnp.sqrt(max(1.0 - rho * rho, 0.0))
    kr, ki = jax.random.split(key)
    C = phy.h_re.shape[0]
    std = jnp.sqrt(0.5).astype(jnp.float32)
    h_re = rho * phy.h_re + innov * std * jax.random.normal(
        kr, (C,), jnp.float32)
    h_im = rho * phy.h_im + innov * std * jax.random.normal(
        ki, (C,), jnp.float32)
    return phy._replace(
        h_re=h_re, h_im=h_im,
        snr_db=instantaneous_snr_db(cfg, h_re, h_im, phy.pathloss_db))


def lazy_fading_coeffs(cfg: CommConfig, steps: Array
                       ) -> tuple[Array, Array]:
    """Closed-form compression of `steps` Gauss-Markov rounds into one
    draw: iterating h' = rho h + sqrt(1-rho^2) CN(0,1) Δ times gives
    exactly

        h_{t+Δ} = rho^Δ h_t + sqrt(1 - rho^(2Δ)) CN(0, 1)

    (the innovations are independent Gaussians, so their weighted sum
    is one Gaussian with the telescoped variance). Returns the
    (rho^Δ, innovation-scale) pair for an int32 `steps` vector; Δ=0
    yields (1, 0) — the identity. The population engine uses this to
    catch idle devices up at O(K) instead of replaying Δ per-round
    draws."""
    rho_d = jnp.power(jnp.float32(cfg.doppler_rho),
                      steps.astype(jnp.float32))
    return rho_d, jnp.sqrt(jnp.maximum(1.0 - rho_d * rho_d, 0.0))


def advance_age(phy: PhyState, mask_eff: Array,
                buffered: Optional[Array] = None) -> PhyState:
    """Refresh the staleness counter after the Aggregate stage: a
    delivered upload resets the worker's age, everyone else ages one
    round (the async/stale-round stage weights by this).

    `buffered` (straggler engine, comm.straggler) marks workers whose
    upload arrived late and is *parked* at the PS rather than dropped:
    the PS has heard from them this round, so their age pins at 1
    (mildly stale) instead of growing like a silent worker's. With
    buffered=None (deadline off) the legacy delivered/undelivered
    behavior is bit-identical."""
    delivered = mask_eff > 0
    aged = jnp.where(delivered, 0, phy.age + 1)
    if buffered is not None:
        aged = jnp.where((buffered > 0) & ~delivered,
                         jnp.ones_like(aged), aged)
    return phy._replace(age=aged)


# ---------------------------------------------------------------------------
# LinkModel: the channel enum decomposed into orthogonal effects
# ---------------------------------------------------------------------------

class LinkModel(NamedTuple):
    """Static resolution of a CommConfig into independent link effects
    (hashable, closed over by the jitted round)."""
    drop_prob: float               # delivery: P(packet lost), 0 = lossless
    awgn: bool                     # distortion: AWGN at the received SNR
    outage_db: Optional[float]     # delivery: SNR outage threshold (None off)
    per_worker: bool               # SNRs differ per worker (fading/pathloss)


def link_model(cfg: CommConfig) -> LinkModel:
    """Decompose the legacy enum + the phy axes. "composite" turns on
    packet loss AND noise together — the combination the enum could
    never express (delivery and distortion are independent axes)."""
    return LinkModel(
        drop_prob=(cfg.drop_prob if cfg.channel in ("erasure", "composite")
                   else 0.0),
        awgn=cfg.channel in ("awgn", "composite"),
        outage_db=cfg.outage_snr_db,
        per_worker=(cfg.fading != "none" or cfg.pathloss_spread_db > 0.0),
    )


def delivery_mask(cfg: CommConfig, mask: Array, key: Array,
                  snr_db: Optional[Array] = None) -> Array:
    """Delivery stage: which selected uploads arrive at the PS. Packet
    erasure (i.i.d. bernoulli, legacy key discipline) composes with SNR
    outage (a worker faded below `outage_snr_db` cannot close the link
    this round — deterministic given the channel state)."""
    link = link_model(cfg)
    out = mask
    if link.drop_prob > 0.0:
        keep = jax.random.bernoulli(key, 1.0 - link.drop_prob, mask.shape)
        out = out * keep.astype(mask.dtype)
    if link.outage_db is not None and snr_db is not None:
        up = (snr_db >= link.outage_db).astype(mask.dtype)
        out = out * up
    return out


def noise_sigma_superposed(cfg: CommConfig, s: Array) -> Array:
    """Legacy analog-aggregation sigma: AWGN on the superposed signal
    at the shared `snr_db`, relative to the superposed RMS power."""
    sig_rms = jnp.sqrt(jnp.mean(s * s))
    return sig_rms * (10.0 ** (-cfg.snr_db / 20.0))


def noise_sigma_per_worker(d: Array, snr_db: Array) -> Array:
    """Per-upload digital decode sigma: each worker's wire leaf is
    distorted at its OWN instantaneous SNR, relative to its own RMS
    power. Returns sigma broadcastable against d (leading worker dim)."""
    C = d.shape[0]
    axes = tuple(range(1, d.ndim))
    rms = jnp.sqrt(jnp.mean(d * d, axis=axes) + 1e-20)     # (C,)
    sigma = rms * (10.0 ** (-snr_db / 20.0))
    return sigma.reshape((C,) + (1,) * (d.ndim - 1))
