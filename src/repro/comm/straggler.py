"""repro.comm.straggler — the deadline-driven straggler engine.

Vanilla DSL assumes every selected upload lands inside the round. This
module makes deadline misses a first-class wire effect, derived from
the physical layer instead of coin-flips:

  late        a selected upload whose airtime (payload bits over the
              SNR->rate model, `budget.worker_airtime_s`) exceeds
              `round_deadline_s` misses the round. It still consumed
              its airtime/energy and advanced the worker's EF residual
              — the transmission happened — but the PS cannot fold it
              into this round's Eq.-7 aggregate.
  buffer      late arrivals are *parked*, not dropped: one dense
              decoded delta + an int32 staleness counter per worker
              (`StragglerBuffer`), carried in both engine states and
              sharded on the mesh path like the EF residual. One slot
              per worker; a newer late delta overwrites an older one.
  drain       on a later round the buffered deltas re-enter the
              aggregate FedBuff-style, discounted by staleness:
              w = 1/(1+age)^gamma. gamma=0 makes a drained delta
              indistinguishable from an on-time one (the telescoping
              property pinned in tests/test_straggler.py); large gamma
              quenches stale directions. The discount composes with
              mean/median/trimmed aggregation (drained rows enter the
              order statistics pre-scaled by their weight).
  quorum      graceful degradation: with fewer than `quorum` deltas
              available (fresh + drained), the PS holds w_t bitwise
              unchanged instead of averaging noise — the downlink
              broadcasts the old model, the PS EF residual is frozen,
              and the buffered deltas wait another round (ageing as
              they do). The event lands in RoundTelemetry.held.
  faults      deterministic worker churn for robustness tests: each
              round every worker starts an R-round outage with
              `fault_prob`, keyed off the round index on a dedicated
              salt (same discipline as population.POP_SALT) — the
              schedule is a pure function of (fault_seed, round), so
              runs replay exactly. A crashed worker transmits nothing:
              no bytes, no airtime, no EF advance.

Aggregation noise discipline: asynchronous arrivals cannot superpose
over the air, so AWGN in straggler mode is always per-upload digital
decode noise (at each worker's own instantaneous SNR when the phy
differentiates them, at the shared budget otherwise); the buffer
stores the noisy decode — the distortion happened at arrival time.

With `round_deadline_s=None` every upload is on time, no buffer state
exists (engine states carry None), and the wire is bit-identical to
the legacy route (golden-pinned in tests/test_rounds.py).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.comm import budget as comm_budget
from repro.comm import channel as comm_channel
from repro.comm import phy as comm_phy
from repro.comm.budget import CommConfig

Array = jax.Array
PyTree = Any

FAULT_SALT = 0xFA  # fault schedule stream = fold_in(PRNGKey(fault_seed),
#                    FAULT_SALT): independent of every training/channel
#                    key, deterministic given (fault_seed, round index)


class StragglerBuffer(NamedTuple):
    """Per-worker parked-delta state (leading worker dim C), carried in
    the engine train states next to the EF residual and sharded the
    same way on the mesh path."""
    delta: PyTree   # (C, ...) f32 dense decoded deltas (zero when empty)
    age: Array      # (C,) int32 rounds since parked; 0 = empty slot


class StragglerStats(NamedTuple):
    """One round of straggler telemetry (f32 scalars, jit-friendly)."""
    late: Array      # selected uploads past the deadline this round
    drained: Array   # buffered deltas folded into this round's aggregate
    buffered: Array  # buffer occupancy after the round
    held: Array      # 1.0 when the quorum gate held the global model


def active(cfg: CommConfig) -> bool:
    """Static: is the straggler engine on? (Python bool under jit.)"""
    return cfg.round_deadline_s is not None


def fault_mode(cfg: CommConfig) -> bool:
    """Static: is deterministic worker churn on?"""
    return cfg.fault_prob > 0.0


def init_buffer(cfg: CommConfig,
                stacked_params: PyTree) -> Optional[StragglerBuffer]:
    """Zero buffered-delta state shaped like the stacked worker models,
    or None when the straggler engine is off — the engine states then
    carry a None pytree node, so legacy configs pay nothing and stay
    structurally identical to before this layer existed."""
    if not active(cfg):
        return None
    leaves = jax.tree.leaves(stacked_params)
    C = leaves[0].shape[0]
    delta = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                         stacked_params)
    return StragglerBuffer(delta=delta, age=jnp.zeros((C,), jnp.int32))


def alive_mask(cfg: CommConfig, round_idx: Array,
               num_workers: int) -> Array:
    """(C,) float mask of workers NOT in an outage at `round_idx`.

    A worker is down iff it drew a crash on any of the last
    `fault_rounds` rounds: outages last exactly R rounds and revive on
    their own. The draw for round t lives on fold_in(stream, t), so the
    schedule is a pure function of the static config and the round
    index — no training key is consumed, and any round's fleet status
    can be recomputed in isolation (same replayability discipline as
    the population engine's POP_SALT cohorts)."""
    stream = jax.random.fold_in(jax.random.PRNGKey(cfg.fault_seed),
                                FAULT_SALT)
    t0 = jnp.asarray(round_idx, jnp.int32)
    down = jnp.zeros((num_workers,), bool)
    for r in range(cfg.fault_rounds):
        t = t0 - r
        crash = jax.random.bernoulli(jax.random.fold_in(stream, t),
                                     cfg.fault_prob, (num_workers,))
        down = down | (crash & (t >= 0))
    return (~down).astype(jnp.float32)


def late_mask(cfg: CommConfig, params: PyTree, mask: Array,
              snr_db: Optional[Array] = None,
              tier_idx: Optional[Array] = None) -> Array:
    """(C,) indicator of selected uploads that miss the round deadline:
    per-worker airtime (payload bytes through the SNR->rate model)
    strictly above `round_deadline_s`. Purely physical — a deep fade or
    a heavy tier makes a worker late, not a coin flip."""
    C = mask.shape[0]
    wb = comm_budget.worker_payload_bytes(cfg, params, C, tier_idx=tier_idx)
    snr = (snr_db if snr_db is not None
           else jnp.full((C,), cfg.snr_db, jnp.float32))
    air = comm_budget.worker_airtime_s(cfg, wb, snr)
    return mask * (air > cfg.round_deadline_s).astype(mask.dtype)


def staleness_weights(cfg: CommConfig, age: Array) -> Array:
    """(C,) FedBuff-style drain discount: 1/(1+age)^gamma for occupied
    slots, 0 for empty ones. gamma=0 -> every buffered delta drains at
    full weight (the telescoping case); larger gamma suppresses stale
    directions harder."""
    occupied = (age > 0).astype(jnp.float32)
    af = age.astype(jnp.float32)
    return occupied * (1.0 + af) ** (-cfg.staleness_gamma)


def aggregate_and_drain(cfg: CommConfig, global_params: PyTree,
                        wire_deltas: PyTree, mask: Array, late: Array,
                        key: Array, snr_db: Optional[Array],
                        buffer: StragglerBuffer
                        ) -> tuple[PyTree, Array, StragglerBuffer,
                                   StragglerStats]:
    """The straggler Aggregate stage: deliver, split fresh/late, drain
    the buffer with staleness discounts, gate on the quorum, and update
    the parked-delta state.

    Consumes the same ekey/nkey split as `channel.receive`, so the
    delivery draw is bit-comparable with the legacy route. Returns
    (w_{t+1}, fresh_mask, new_buffer, stats) where fresh_mask marks the
    on-time deliveries — the uploads inside THIS round's aggregate
    (late-but-parked arrivals are accounted separately, via
    stats/advance_age's `buffered` channel)."""
    link = comm_phy.link_model(cfg)
    ekey, nkey = jax.random.split(key)
    delivered = comm_phy.delivery_mask(cfg, mask, ekey, snr_db=snr_db)
    fresh = delivered * (1.0 - late)
    late_arrivals = delivered * late

    g_leaves, treedef = jax.tree.flatten(global_params)
    d_leaves = jax.tree.leaves(wire_deltas)
    b_leaves = jax.tree.leaves(buffer.delta)

    # distortion at arrival time: per-upload digital decode noise (an
    # async round has no analog superposition to ride), same per-leaf
    # fold_in(nkey, i) streams as channel.receive
    noisy = []
    for i, d in enumerate(d_leaves):
        d = d.astype(jnp.float32)
        if link.awgn:
            snr_for_noise = (snr_db if link.per_worker and snr_db is not None
                             else jnp.full((d.shape[0],), cfg.snr_db,
                                           jnp.float32))
            sigma = comm_phy.noise_sigma_per_worker(d, snr_for_noise)
            d = d + sigma * jax.random.normal(jax.random.fold_in(nkey, i),
                                              d.shape, jnp.float32)
        noisy.append(d)

    w_drain = staleness_weights(cfg, buffer.age)
    n_drain = (buffer.age > 0).astype(jnp.float32).sum()
    available = fresh.sum() + n_drain
    held = ((available < cfg.quorum) if cfg.quorum > 0
            else jnp.zeros((), bool))

    # 2C-row aggregate: fresh uploads at weight 1, drained buffer
    # entries at their staleness discount
    weights = jnp.concatenate([fresh.astype(jnp.float32), w_drain])
    participants = (weights > 0).astype(jnp.float32)

    if cfg.aggregator == "mean":
        # FedBuff convention: discounted numerator over the participant
        # count — a lone very-stale delta moves the model by w*d, and
        # with no drained entries this is exactly the legacy masked mean
        denom = jnp.maximum(participants.sum(), 1.0)
        out = []
        for g, d, b in zip(g_leaves, noisy, b_leaves):
            rows = jnp.concatenate([d, b.astype(jnp.float32)], axis=0)
            w = weights.reshape((-1,) + (1,) * (rows.ndim - 1))
            out.append((g + (w * rows).sum(axis=0) / denom).astype(g.dtype))
        agg = jax.tree.unflatten(treedef, out)
    else:
        # median / trimmed mean: drained rows enter the order statistics
        # pre-scaled by their discount; noise is already applied, so the
        # robust path runs with distortion off
        rows_leaves = []
        for d, b in zip(noisy, b_leaves):
            rows = jnp.concatenate([d, b.astype(jnp.float32)], axis=0)
            w = weights.reshape((-1,) + (1,) * (rows.ndim - 1))
            rows_leaves.append(w * rows)
        rows_tree = jax.tree.unflatten(treedef, rows_leaves)
        quiet = link._replace(awgn=False)
        agg = comm_channel._robust_receive(cfg, quiet, global_params,
                                           rows_tree, participants, nkey,
                                           snr_db=None)

    # quorum hold: w_t survives bitwise (pinned in tests)
    out_params = jax.tree.map(lambda g, a: jnp.where(held, g, a),
                              global_params, agg)

    # buffer lifecycle: late arrivals park (newest delta wins the slot,
    # age 1); on a held round fresh arrivals park too and surviving
    # entries age one more round; on an applied round every occupied
    # slot drained above, so it clears
    occupied = buffer.age > 0
    parked = (late_arrivals > 0) | (held & (fresh > 0))
    kept = occupied & held & ~parked
    new_age = jnp.where(parked, 1,
                        jnp.where(kept, buffer.age + 1, 0)
                        ).astype(jnp.int32)

    def buf_leaf(d, b):
        p = parked.reshape((-1,) + (1,) * (d.ndim - 1))
        k = kept.reshape((-1,) + (1,) * (d.ndim - 1))
        return jnp.where(p, d, jnp.where(k, b, 0.0)).astype(jnp.float32)

    new_delta = jax.tree.unflatten(
        treedef, [buf_leaf(d, b) for d, b in zip(noisy, b_leaves)])
    new_buffer = StragglerBuffer(delta=new_delta, age=new_age)

    stats = StragglerStats(
        late=(mask * late).sum().astype(jnp.float32),
        drained=jnp.where(held, 0.0, n_drain).astype(jnp.float32),
        buffered=(new_age > 0).sum().astype(jnp.float32),
        held=held.astype(jnp.float32))
    return out_params, fresh, new_buffer, stats
