from repro.configs.base import ArchConfig, InputShape, INPUT_SHAPES, get_arch, list_archs
