"""Snowflake Arctic-480B [hf:Snowflake/snowflake-arctic-base]: dense-MoE
hybrid — 35L, d_model 7168, 56 heads (GQA kv=8), 128 experts top-2 with
per-expert d_ff 4864, PLUS a parallel dense residual MLP per layer,
vocab 32000."""
from repro.configs.base import ArchConfig, ATTN

CONFIG = ArchConfig(
    name="arctic-480b", family="moe",
    source="hf:Snowflake/snowflake-arctic-base",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=4864, vocab_size=32000,
    block_pattern=(ATTN,),
    num_experts=128, experts_per_token=2, dense_residual=True,
    swarm_mode="fsdp",
    subquadratic=False,
)
