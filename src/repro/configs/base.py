"""Architecture + input-shape configuration.

Every assigned architecture is one `ArchConfig` in its own module under
`repro.configs`; `get_arch(name)` resolves them. `reduced()` produces the
CPU smoke-test variant (<=2 layers, d_model<=512, <=4 experts) of the same
family, as required by the brief.
"""
from __future__ import annotations

import dataclasses
import importlib
import math

# block kinds understood by models/transformer.py
ATTN = "attn"            # full causal GQA attention
SWA = "swa"              # sliding-window causal attention
RGLRU = "rglru"          # RG-LRU recurrent block (RecurrentGemma)
MLSTM = "mlstm"          # xLSTM matrix-memory block
SLSTM = "slstm"          # xLSTM scalar-memory block


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | hybrid | vlm | audio | ssm
    source: str                       # citation from the assignment
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads
    # layer pattern, cycled over depth, e.g. ("rglru","rglru","swa")
    block_pattern: tuple[str, ...] = (ATTN,)
    window_size: int = 0              # for swa blocks
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    dense_residual: bool = False      # arctic: dense MLP in parallel with MoE
    moe_capacity_factor: float = 1.25 # >= E/K => dropless (tests)
    # encoder-decoder (audio)
    encoder_layers: int = 0
    cross_attention: bool = False
    encoder_memory_len: int = 4096    # encoder output length consumed at decode
    # modality frontend stub (vlm/audio): inputs are precomputed embeddings
    input_mode: str = "tokens"        # tokens | embeddings | tokens+prefix
    prefix_len: int = 0               # vlm: image-patch embedding prefix length
    # misc
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # capability flags
    subquadratic: bool = False        # may run long_500k
    remat: bool = True                # per-layer-group activation ckpt
    # microbatches for the train step's grad accumulation (0 = auto:
    # 8 for fsdp-mode archs whose per-device activations exceed HBM)
    train_microbatches: int = 0
    # swarm deployment mode (DESIGN.md 3): "tp" = worker per data-axis
    # group, replica TP-sharded; "fsdp" = time-multiplexed swarm (1 spatial
    # worker single-pod / 1 per pod multi-pod), replica FSDP+TP-sharded
    swarm_mode: str = "tp"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def padded_vocab(self, multiple: int = 2048) -> int:
        return math.ceil(self.vocab_size / multiple) * multiple

    def _block_params(self) -> dict[str, int]:
        """Analytic per-block parameter counts (matches models/transformer.py)."""
        d, hd = self.d_model, self.resolved_head_dim
        attn = (d * hd * (self.num_heads + 2 * self.num_kv_heads)  # qkv
                + self.num_heads * hd * d)                          # out proj
        return {
            ATTN: attn,
            SWA: attn,
            # in/gate/out projections + recurrence gates (d_rnn = d)
            RGLRU: 3 * d * d + 2 * d * d + 3 * d,
            # up(2d) + qkv in expanded space + out; expansion factor 2
            MLSTM: 2 * d * (2 * d) + 3 * (2 * d) * (2 * d) + (2 * d) * d,
            # 4 gates, recurrent + input weights in d
            SLSTM: 8 * d * d,
        }

    def _mixer_params(self) -> int:
        """Per-layer channel-mixer (FFN / MoE) parameter count."""
        d = self.d_model
        out = 0
        if self.num_experts:
            out += self.num_experts * 3 * d * self.d_ff  # expert FFNs (gated)
            out += d * self.num_experts                   # router
            if self.dense_residual:
                out += 3 * d * self.d_ff                  # arctic parallel dense MLP
        elif self.d_ff:
            out += 3 * d * self.d_ff
        return out

    def param_count(self) -> int:
        """Analytic parameter count, for roofline MODEL_FLOPS = 6*N*D."""
        d = self.d_model
        per_block = self._block_params()
        n = self.vocab_size * d  # token embedding (tied output head)
        for i in range(self.num_layers):
            kind = self.block_pattern[i % len(self.block_pattern)]
            n += per_block[kind] + self._mixer_params()
            if self.cross_attention:
                n += per_block[ATTN]  # cross-attention per decoder layer
        if self.encoder_layers:
            n += self.encoder_layers * (per_block[ATTN] + 3 * d * self.d_ff)
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if not self.num_experts:
            return self.param_count()
        d = self.d_model
        inactive = (self.num_layers *
                    (self.num_experts - self.experts_per_token) *
                    3 * d * self.d_ff)
        return self.param_count() - inactive

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family/pattern, tiny dims. Long
        block patterns (xlstm 7:1) are deduped to one block per kind so
        the smoke model stays <=4 layers while covering every kind."""
        pattern = self.block_pattern
        if len(pattern) > 4:
            pattern = tuple(dict.fromkeys(pattern))
        pat = len(pattern)
        layers = max(2, pat) if pat > 2 else 2
        d_model = min(self.d_model, 128)
        heads = max(1, min(self.num_heads, 4))
        kv = max(1, min(self.num_kv_heads, heads))
        while heads % kv:
            kv -= 1
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            block_pattern=pattern,
            num_layers=layers,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=d_model // heads,
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            experts_per_token=min(self.experts_per_token, 2) if self.num_experts else 0,
            encoder_layers=min(self.encoder_layers, 2) if self.encoder_layers else 0,
            window_size=min(self.window_size, 64) if self.window_size else 0,
            encoder_memory_len=64 if self.encoder_layers else self.encoder_memory_len,
            prefix_len=min(self.prefix_len, 16) if self.prefix_len else 0,
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

ARCH_MODULES = [
    "qwen3_moe_30b_a3b", "deepseek_67b", "recurrentgemma_9b",
    "llava_next_34b", "seamless_m4t_large_v2", "xlstm_350m",
    "smollm_360m", "starcoder2_7b", "arctic_480b", "stablelm_3b",
    "paper_cnn",
]


def list_archs() -> list[str]:
    out = []
    for mod in ARCH_MODULES:
        m = importlib.import_module(f"repro.configs.{mod}")
        if hasattr(m, "CONFIG"):
            out.append(m.CONFIG.name)
    return out


def get_arch(name: str) -> ArchConfig:
    key = name.replace("-", "_").replace(".", "_")
    m = importlib.import_module(f"repro.configs.{key}")
    return m.CONFIG
