"""DeepSeek-67B [arXiv:2401.02954]: llama-arch dense, 95L, d_model 8192,
64 heads (GQA kv=8), d_ff 22016, vocab 102400."""
from repro.configs.base import ArchConfig, ATTN

CONFIG = ArchConfig(
    name="deepseek-67b", family="dense",
    source="arXiv:2401.02954",
    num_layers=95, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22016, vocab_size=102400,
    block_pattern=(ATTN,),
    rope_theta=10_000.0,
    swarm_mode="fsdp",
    subquadratic=False,
)
