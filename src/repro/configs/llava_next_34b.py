"""LLaVA-NeXT-34B [hf:llava-hf/llava-v1.6-mistral-7b-hf]: VLM — language
backbone 60L, d_model 7168, 56 heads (GQA kv=8), d_ff 20480, vocab 64000.
Vision tower + anyres tiling projector are STUBBED per the brief: inputs
include precomputed patch-embedding prefixes (anyres tiling yields up to
2880 image tokens; we provision a 2880-token prefix)."""
from repro.configs.base import ArchConfig, ATTN

CONFIG = ArchConfig(
    name="llava-next-34b", family="vlm",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=20480, vocab_size=64000,
    block_pattern=(ATTN,),
    input_mode="tokens+prefix", prefix_len=2880,
    rope_theta=1_000_000.0,
    swarm_mode="fsdp",
    subquadratic=False,
)
