"""The paper's own experimental models (§V-A): 5-layer CNN [9] and a
compact ResNet on (synthetic) MNIST/CIFAR10-like data. Not a transformer
config — exposes the ImageModel factories used by the M-DSL repro."""
from repro.data.synthetic import CIFAR_LIKE, MNIST_LIKE
from repro.models.cnn import make_cnn5, make_resnet

def paper_cnn(spec=MNIST_LIKE, width_mult: int = 8):
    return make_cnn5(spec.height, spec.width, spec.channels,
                     spec.num_classes, width_mult)

def paper_resnet(spec=CIFAR_LIKE, width_mult: int = 8):
    return make_resnet(spec.height, spec.width, spec.channels,
                       spec.num_classes, width_mult)
