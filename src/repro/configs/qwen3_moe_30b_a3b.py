"""Qwen3-MoE-30B-A3B [hf:Qwen/Qwen3-30B-A3B]: 48L, d_model 2048, 32 heads
(GQA kv=4), per-expert d_ff 768, vocab 151936, 128 experts top-8."""
from repro.configs.base import ArchConfig, ATTN

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    source="hf:Qwen/Qwen3-30B-A3B",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
    head_dim=128,  # Qwen3 uses head_dim 128 (not d_model/heads)
    d_ff=768, vocab_size=151936,
    block_pattern=(ATTN,),
    num_experts=128, experts_per_token=8,
    rope_theta=1_000_000.0,
    swarm_mode="fsdp",
    subquadratic=False,
)
