"""RecurrentGemma-9B [arXiv:2402.19427]: Griffin hybrid — RG-LRU recurrent
blocks + local (sliding-window 2048) attention in a 2:1 pattern, 38L,
d_model 4096, 16 heads (MQA kv=1), d_ff 12288, vocab 256000."""
from repro.configs.base import ArchConfig, RGLRU, SWA

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    source="arXiv:2402.19427",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    d_ff=12288, vocab_size=256000,
    block_pattern=(RGLRU, RGLRU, SWA),  # 1:2 attention:recurrent
    window_size=2048,
    subquadratic=True,  # constant-state recurrence + windowed attention
)
