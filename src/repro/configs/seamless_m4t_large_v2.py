"""SeamlessM4T-large-v2 [arXiv:2308.11596]: encoder-decoder multimodal
translator. Backbone only per the brief: 24 decoder layers with
cross-attention + 24 encoder layers, d_model 1024, 16 heads (kv=16 = MHA),
d_ff 8192, vocab 256206. The mel-spectrogram + conformer feature frontend
is STUBBED: encoder consumes precomputed frame embeddings (B, M, d)."""
from repro.configs.base import ArchConfig, ATTN

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="audio",
    source="arXiv:2308.11596",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=8192, vocab_size=256206,
    block_pattern=(ATTN,),
    encoder_layers=24, cross_attention=True, encoder_memory_len=4096,
    subquadratic=False,
)
