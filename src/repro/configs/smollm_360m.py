"""SmolLM-360M [hf:HuggingFaceTB/SmolLM-135M family]: llama-arch small
dense, 32L, d_model 960, 15 heads (GQA kv=5), d_ff 2560, vocab 49152."""
from repro.configs.base import ArchConfig, ATTN

CONFIG = ArchConfig(
    name="smollm-360m", family="dense",
    source="hf:HuggingFaceTB/SmolLM-135M",
    num_layers=32, d_model=960, num_heads=15, num_kv_heads=5,
    d_ff=2560, vocab_size=49152,
    block_pattern=(ATTN,),
    subquadratic=False,
)
