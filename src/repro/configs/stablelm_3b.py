"""StableLM-3B [hf:stabilityai/stablelm-2-1_6b family]: dense, 32L,
d_model 2560, 32 heads (kv=32 => full MHA), d_ff 6912, vocab 50304."""
from repro.configs.base import ArchConfig, ATTN

CONFIG = ArchConfig(
    name="stablelm-3b", family="dense",
    source="hf:stabilityai/stablelm-2-1_6b",
    num_layers=32, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=6912, vocab_size=50304,
    block_pattern=(ATTN,),
    subquadratic=False,
)
