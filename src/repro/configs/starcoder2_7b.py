"""StarCoder2-7B [arXiv:2402.19173]: dense with GQA + RoPE and
sliding-window attention (window 4096), 32L, d_model 4608, 36 heads
(GQA kv=4), d_ff 18432, vocab 49152. The sliding window makes it
sub-quadratic => runs long_500k with a ring-buffer KV cache."""
from repro.configs.base import ArchConfig, SWA

CONFIG = ArchConfig(
    name="starcoder2-7b", family="dense",
    source="arXiv:2402.19173",
    num_layers=32, d_model=4608, num_heads=36, num_kv_heads=4,
    d_ff=18432, vocab_size=49152,
    block_pattern=(SWA,),
    window_size=4096,
    rope_theta=100_000.0,
    subquadratic=True,  # bounded window
)
