"""xLSTM-350M [arXiv:2405.04517]: mLSTM (matrix-memory, chunk-parallel)
and sLSTM (scalar-memory, sequential) blocks at the paper's main xLSTM[7:1]
ratio, 24L, d_model 1024, 4 heads, d_ff 0 (blocks embed their own
projections), vocab 50304.

The 7:1 ratio matters for TPU cost: each sLSTM layer is a genuinely
sequential scan over time (the paper's own §2.3 — not parallelizable), so
sLSTM count directly multiplies the serial-step fraction of the roofline
(EXPERIMENTS.md §Perf iteration 6)."""
from repro.configs.base import ArchConfig, MLSTM, SLSTM

CONFIG = ArchConfig(
    name="xlstm-350m", family="ssm",
    source="arXiv:2405.04517",
    num_layers=24, d_model=1024, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    block_pattern=(MLSTM,) * 7 + (SLSTM,),   # xLSTM[7:1]
    subquadratic=True,  # constant-state recurrence
)
