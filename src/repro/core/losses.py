"""Loss functions.

The paper's Eq. 3 defines the evaluation loss as per-sample RMSE between
model output and label. For an L-class task we realize it as the RMSE
between the softmax probability vector and the one-hot label (smooth,
bounded, minimized exactly at the correct confident prediction — the
natural reading of Eq. 3 for classification). Cross-entropy is also
provided; the selection machinery is loss-agnostic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def rmse_loss(logits: Array, labels: Array, num_classes: int) -> Array:
    """Eq. 3: mean over samples of sqrt(||softmax(logits) - onehot||^2)."""
    probs = jax.nn.softmax(logits, axis=-1)
    one_hot = jax.nn.one_hot(labels, num_classes, dtype=probs.dtype)
    per_sample = jnp.sqrt(jnp.sum((probs - one_hot) ** 2, axis=-1) + 1e-12)
    return per_sample.mean()


def cross_entropy_loss(logits: Array, labels: Array, num_classes: int) -> Array:
    log_probs = jax.nn.log_softmax(logits, axis=-1)
    one_hot = jax.nn.one_hot(labels, num_classes, dtype=logits.dtype)
    return -(one_hot * log_probs).sum(axis=-1).mean()


def accuracy(logits: Array, labels: Array) -> Array:
    return (jnp.argmax(logits, axis=-1) == labels).mean()


LOSSES = {"rmse": rmse_loss, "xent": cross_entropy_loss}
