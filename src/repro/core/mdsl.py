"""M-DSL communication round and baselines (paper Algorithm 1 + §V-B).

One engine, four algorithms, differing only in (a) the local update rule
and (b) the selection rule:

  fedavg    SGD local epochs, all workers aggregated           [17]
  dsl       PSO-hybrid local update, single best worker        [9]
  multi_dsl PSO-hybrid, multi-worker selection with tau=1
            (score = F only; the paper's ablation in Fig. 3)
  mdsl      PSO-hybrid, multi-worker selection with
            theta = tau*F + (1-tau)*eta  (the contribution)

The round is a configuration of `core/rounds.py`'s stage pipeline:
this module supplies only the LocalUpdate stage (PSO-hybrid local
epochs, vmap'ed over the leading C dim) and the WorkerState-shaped
best tracking; ScoreSelect, Uplink, Aggregate, Downlink, and the byte
accounting are the shared stages in `rounds.RoundPipeline`. The same
pipeline drives the mesh-distributed production trainer
(`core/swarm_dist.py`), where the worker dim is sharded over mesh axes.

Granularity note (DESIGN.md §1): Algorithm 1 applies Eq. 8 once per
communication round while §V-A trains 4 local epochs per round. We
therefore run E epochs of minibatch SGD and treat the accumulated local
progress as Eq. 8's "-alpha grad F" term, adding the PSO velocity /
cognitive / social terms once per round. With E=1 and a single full-batch
step this reduces exactly to Eq. 8. Per-step PSO is available via
`pso_every_step=True` for the convergence unit tests.

F_{i,t} used for bests and selection is evaluated on the shared synthetic
dataset D_g ("workers also have a synthetic global dataset D_g for
function value evaluation", §III-A) so scores are comparable across
workers; the training gradient uses the local D_i.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.comm import channel as comm_channel
from repro.comm import compress as comm_compress
from repro.comm import phy as comm_phy
from repro.comm import straggler as comm_straggler
from repro.comm.budget import CommConfig
from repro.core import pso, rounds, selection
from repro.core.pso import (GlobalBest, PsoCoefficients, PsoHyperParams,
                            WorkerState)
from repro.core.rounds import RoundTelemetry
from repro.core.selection import SelectionState

Array = jax.Array
PyTree = Any
LossFn = Callable[[PyTree, Array, Array], Array]  # (params, x, y) -> scalar

# pre-refactor alias: the paper path's metrics are the unified telemetry
RoundMetrics = RoundTelemetry


class MdslConfig(NamedTuple):
    algorithm: str = "mdsl"          # fedavg | dsl | multi_dsl | mdsl
    tau: float = 0.9                 # Eq. 5 regularizer (paper §V-A)
    local_epochs: int = 4            # paper §V-A
    batch_size: int = 64             # paper §V-A
    hp: PsoHyperParams = PsoHyperParams()
    pso_every_step: bool = False     # per-step Eq. 8 (unit tests)
    comm: CommConfig = CommConfig()  # wire: compression/channel/aggregation


class SwarmTrainState(NamedTuple):
    """Full state of the distributed system. Worker leaves carry a leading
    C dim."""
    workers: WorkerState             # stacked over C
    global_params: PyTree            # w_t (replicated)
    gbest: GlobalBest                # Eq. 10 view
    sel: SelectionState
    round_idx: Array                 # t
    eta: Array                       # (C,) non-iid degrees (static over rounds)
    residual: PyTree                 # (C, ...) uplink error-feedback state
    ps_residual: PyTree              # PS-side downlink error-feedback state
    phy: comm_phy.PhyState           # per-worker channel state (comm.phy)
    # (C, ...) parked late deltas + staleness ages (comm.straggler);
    # None unless comm.round_deadline_s is set
    buffer: Any = None


def init_state(key: Array, init_params_fn: Callable[[Array], PyTree],
               num_workers: int, eta: Array,
               comm: CommConfig = CommConfig()) -> SwarmTrainState:
    """All workers start from a common global init (Algorithm 1 line 0).
    `comm` seeds the physical-layer state (pathloss profile, unit-gain
    fading) — pass the run's wire config when it uses phy axes."""
    params = init_params_fn(key)
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (num_workers,) + x.shape), params)
    workers = jax.vmap(pso.init_worker_state)(stacked)
    return SwarmTrainState(
        workers=workers,
        global_params=params,
        gbest=pso.init_global_best(params),
        sel=selection.init_selection_state(),
        round_idx=jnp.zeros((), jnp.int32),
        eta=eta,
        residual=comm_compress.init_residual(stacked),
        ps_residual=rounds.init_ps_residual(params),
        phy=comm_phy.init_state(comm, num_workers),
        buffer=comm_straggler.init_buffer(comm, stacked),
    )


def _local_sgd_epochs(params: PyTree, data_x: Array, data_y: Array,
                      loss_fn: LossFn, lr: Array, cfg: MdslConfig,
                      key: Array) -> PyTree:
    """E epochs of minibatch SGD on one worker's local dataset."""
    n = data_x.shape[0]
    bs = min(cfg.batch_size, n)
    steps = n // bs
    grad_fn = jax.grad(loss_fn)

    def epoch(params, ekey):
        perm = jax.random.permutation(ekey, n)
        xb = data_x[perm[: steps * bs]].reshape((steps, bs) + data_x.shape[1:])
        yb = data_y[perm[: steps * bs]].reshape((steps, bs) + data_y.shape[1:])

        def step(p, batch):
            x, y = batch
            return pso.sgd_step(p, grad_fn(p, x, y), lr), None

        params, _ = jax.lax.scan(step, params, (xb, yb))
        return params, None

    params, _ = jax.lax.scan(epoch, params,
                             jax.random.split(key, cfg.local_epochs))
    return params


def _local_update(state: WorkerState, gbest_params: PyTree, data_x: Array,
                  data_y: Array, loss_fn: LossFn, coeffs: PsoCoefficients,
                  lr: Array, cfg: MdslConfig, key: Array,
                  use_pso: bool) -> WorkerState:
    """One worker's round-t local update: PSO terms (Eq. 8) + E SGD epochs."""
    if use_pso and cfg.pso_every_step:
        # Faithful single-step Eq. 8, repeated over minibatches.
        n = data_x.shape[0]
        bs = min(cfg.batch_size, n)
        steps = (n // bs) * cfg.local_epochs
        perm = jax.random.permutation(key, n)
        idx = jnp.resize(perm, (steps * bs,)).reshape(steps, bs)
        grad_fn = jax.grad(loss_fn)

        def step(s, i):
            g = grad_fn(s.params, data_x[i], data_y[i])
            return pso.pso_step(s, gbest_params, g, coeffs, lr, cfg.hp), None

        state, _ = jax.lax.scan(step, state, idx)
        return state

    # Round-level Eq. 8: PSO displacement once + accumulated SGD progress.
    w0 = state.params
    trained = _local_sgd_epochs(w0, data_x, data_y, loss_fn, lr, cfg, key)
    sgd_delta = jax.tree.map(lambda a, b: a - b, trained, w0)
    if not use_pso:  # fedavg
        return state._replace(params=trained,
                              velocity=sgd_delta)

    def leaf(w, v, wl, wg, d):
        v_new = coeffs.c0 * v + coeffs.c1 * (wl - w) + coeffs.c2 * (wg - w) + d
        if cfg.hp.velocity_clip > 0.0:
            v_new = jnp.clip(v_new, -cfg.hp.velocity_clip, cfg.hp.velocity_clip)
        return v_new

    v_next = jax.tree.map(leaf, w0, state.velocity, state.best_params,
                          gbest_params, sgd_delta)
    return state._replace(params=jax.tree.map(jnp.add, w0, v_next),
                          velocity=v_next)


@functools.partial(jax.jit,
                   static_argnames=("loss_fn", "eval_fn", "cfg", "n_params"))
def mdsl_round(state: SwarmTrainState, data_x: Array, data_y: Array,
               eval_x: Array, eval_y: Array, key: Array, *,
               loss_fn: LossFn, eval_fn: LossFn, cfg: MdslConfig,
               n_params: int) -> tuple[SwarmTrainState, RoundTelemetry]:
    """One communication round (Algorithm 1 body).

    data_x/data_y: stacked local datasets (C, n_i, ...); eval_x/eval_y:
    the shared synthetic D_g. Returns the next state and round telemetry.
    """
    C = data_x.shape[0]
    use_pso = cfg.algorithm != "fedavg"
    pipe = rounds.RoundPipeline(algorithm=cfg.algorithm, comm=cfg.comm,
                                num_workers=C, tau=cfg.tau,
                                n_params=n_params)

    ckey, tkey, bkey, qkey, wkey = jax.random.split(key, 5)
    # per-WORKER coefficient draws (classic PSO: each particle has its
    # own random factors). A shared draw hits every worker with the same
    # bad perturbation, leaving the selection rule nothing to filter —
    # per-worker draws are what let Eq. 6 reject derailed workers.
    coeffs = jax.vmap(pso.sample_coefficients)(jax.random.split(ckey, C))
    lr = pso.decayed_lr(cfg.hp, state.round_idx)

    # --- LocalUpdate (Algorithm 1 lines 3-4): bests, update, F_{i,t+1}. ---
    with rounds.stage_span("LocalUpdate"):
        eval_on_dg = lambda p: eval_fn(p, eval_x, eval_y)
        pre_losses = jax.vmap(eval_on_dg)(state.workers.params)
        workers = jax.vmap(pso.update_local_best)(state.workers, pre_losses)

        prev_params = workers.params
        local = functools.partial(_local_update, loss_fn=loss_fn,
                                  lr=lr, cfg=cfg, use_pso=use_pso)
        workers = jax.vmap(
            lambda s, x, y, k, c: local(s, state.gbest.params, x, y, key=k,
                                        coeffs=c)
        )(workers, data_x, data_y, jax.random.split(tkey, C), coeffs)

        # Byzantine workers compute adversarial updates (comm/channel.py);
        # corruption lands in their params so Eq. 6 can see (and reject
        # it).
        workers = workers._replace(params=comm_channel.corrupt_local_updates(
            cfg.comm, prev_params, workers.params, bkey))

        eval_losses = jax.vmap(eval_on_dg)(workers.params)

    # --- ScoreSelect (lines 5-6, Eqs. 4-6). ---
    theta, mask, theta_mean = pipe.select(eval_losses, state.eta,
                                          state.sel.prev_theta_mean)

    # --- Uplink -> Aggregate -> Downlink (lines 7-9, Eq. 7 through the
    # wire). With the default CommConfig this is exactly the seed's
    # masked delta-mean and a dense broadcast. ---
    delta = jax.tree.map(lambda a, b: a - b, workers.params, prev_params)
    out = pipe.wire(delta=delta, theta=theta, mask=mask,
                    global_params=state.global_params,
                    residual=state.residual, ps_residual=state.ps_residual,
                    qkey=qkey, wkey=wkey, phy=state.phy,
                    buffer=state.buffer, round_idx=state.round_idx)

    # --- BestTracking (Eq. 10) + next state. ---
    with rounds.stage_span("BestTracking"):
        global_loss = eval_on_dg(out.global_params)
        gbest = pso.update_global_best(state.gbest, out.global_params,
                                       global_loss)
    next_state = SwarmTrainState(
        workers=workers, global_params=out.global_params, gbest=gbest,
        sel=SelectionState(prev_theta_mean=theta_mean),
        round_idx=state.round_idx + 1, eta=state.eta,
        residual=out.residual, ps_residual=out.ps_residual, phy=out.phy,
        buffer=out.buffer)
    return next_state, pipe.telemetry(losses=eval_losses, theta=theta,
                                      mask=mask, global_loss=global_loss,
                                      outcome=out)


count_params = rounds.count_params
