"""Non-i.i.d. degree metric (paper §II, Eq. 2).

Quantifies label-distribution skew of each worker's local dataset against a
global reference dataset, via

    eta_i = Normalize( beta1 * |L_i|/|L_g|  +  beta2 * W_i  +  phi )

where W_i is the Wasserstein distance between the worker's label
distribution and the global label distribution (Eq. 1 specialized to the
discrete label marginal — the paper evaluates label skew, for which the
1-D discrete WD over the ordered label alphabet is exact), |L_i|/|L_g| is
the label-ratio (fraction of global label types present locally), and
Normalize is min-max scaling across the worker population (paper [13]).

The coefficients (beta1, beta2, phi) are fitted by least squares against
observed distributed-learning accuracy over a Dirichlet-alpha sweep
(paper §V-C); `fit_eta_coefficients` reproduces that procedure.

Everything here is pure JAX and shape-polymorphic so it can run inside a
pjit'ed program (the per-worker label histogram is the only cross-worker
communication the metric ever needs: an all-gather of (L,) vectors).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def label_histogram(labels: Array, num_classes: int) -> Array:
    """Counts per class. labels: int array, any shape -> (num_classes,) f32."""
    one_hot = jax.nn.one_hot(labels.reshape(-1), num_classes, dtype=jnp.float32)
    return one_hot.sum(axis=0)


def label_distribution(labels: Array, num_classes: int) -> Array:
    """Normalized label marginal Pr_D(y); safe for empty datasets."""
    hist = label_histogram(labels, num_classes)
    total = hist.sum()
    return jnp.where(total > 0, hist / jnp.maximum(total, 1.0),
                     jnp.full_like(hist, 1.0 / num_classes))


def wasserstein_1d(p: Array, q: Array) -> Array:
    """Discrete 1-D Wasserstein-1 distance between label marginals.

    For distributions supported on the ordered alphabet {0..L-1} with unit
    ground metric |i - j|, W1(p, q) = sum_k |CDF_p(k) - CDF_q(k)|  (exact
    closed form of Eq. 1 for label marginals).
    """
    cdf_p = jnp.cumsum(p)
    cdf_q = jnp.cumsum(q)
    return jnp.abs(cdf_p - cdf_q).sum()


def label_ratio(local_hist: Array, global_hist: Array) -> Array:
    """|L_i| / |L_g|: fraction of globally-present label types present locally."""
    present_local = (local_hist > 0) & (global_hist > 0)
    present_global = global_hist > 0
    return present_local.sum().astype(jnp.float32) / jnp.maximum(
        present_global.sum().astype(jnp.float32), 1.0)


def minmax_normalize(x: Array, eps: float = 1e-12) -> Array:
    """Min-max scaling across the worker population (paper [13])."""
    lo, hi = x.min(), x.max()
    return (x - lo) / jnp.maximum(hi - lo, eps)


class EtaCoefficients(NamedTuple):
    """Fitted coefficients of Eq. 2. Paper §V-C reports
    (0.286, -0.07, 0.592) for CIFAR10 and (-0.031, 0.127, -0.04) for MNIST."""
    beta1: float
    beta2: float
    phi: float


# Paper §V-C reference values.
CIFAR10_COEFFS = EtaCoefficients(beta1=0.286, beta2=-0.07, phi=0.592)
MNIST_COEFFS = EtaCoefficients(beta1=-0.031, beta2=0.127, phi=-0.04)


@functools.partial(jax.jit, static_argnames=("num_classes",))
def noniid_features(local_labels: Array, global_labels: Array,
                    num_classes: int) -> tuple[Array, Array]:
    """Per-worker raw features (label_ratio, W_i) of Eq. 2."""
    local_hist = label_histogram(local_labels, num_classes)
    global_hist = label_histogram(global_labels, num_classes)
    p = label_distribution(local_labels, num_classes)
    q = label_distribution(global_labels, num_classes)
    return label_ratio(local_hist, global_hist), wasserstein_1d(p, q)


def noniid_degree(ratios: Array, wds: Array,
                  coeffs: EtaCoefficients = CIFAR10_COEFFS) -> Array:
    """Eq. 2: eta (the non-i.i.d. DEGREE) over the worker population.

    The beta-coefficients are fitted against observed distributed-learning
    ACCURACY (paper SS V-C), so the raw affine form is an accuracy proxy:
    HIGH = iid-like data. The degree is its complement -- the paper's
    Fig. 1 plots "non-i.i.d. degree 1-eta" as the accuracy-tracking
    curve, and Eq. 5/6's selection keeps workers with LOW theta = low
    loss AND low degree (good data). Returning the un-complemented proxy
    inverts the selection signal (it then prefers the MOST heterogeneous
    workers -- measurably worse than Multi-DSL, see EXPERIMENTS.md
    SS Paper-validation).
    ratios, wds: (C,) -> eta (C,) in [0, 1], 1 = most heterogeneous."""
    raw = coeffs.beta1 * ratios + coeffs.beta2 * wds + coeffs.phi
    return 1.0 - minmax_normalize(raw)


def noniid_degree_from_labels(per_worker_labels: Array, global_labels: Array,
                              num_classes: int,
                              coeffs: EtaCoefficients = CIFAR10_COEFFS) -> Array:
    """eta for a stacked (C, n_i) int label array + (n_g,) global labels."""
    ratios, wds = jax.vmap(
        lambda l: noniid_features(l, global_labels, num_classes))(per_worker_labels)
    return noniid_degree(ratios, wds, coeffs)


def fit_eta_coefficients(ratios: np.ndarray, wds: np.ndarray,
                         accuracies: np.ndarray,
                         train_frac: float = 0.9,
                         seed: int = 0) -> tuple[EtaCoefficients, float, float]:
    """Least-squares fit of Eq. 2 to observed accuracy (paper §V-C).

    Fits acc ~ beta1 * ratio + beta2 * WD + phi on `train_frac` of the
    records, returns (coeffs, R^2_train, R^2_test). Uses 90/10 split like
    the paper ("90% records to fit ... 10% to test").
    """
    n = len(accuracies)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    n_train = max(int(round(train_frac * n)), 2)
    tr, te = perm[:n_train], perm[n_train:]

    def design(idx):
        return np.stack([ratios[idx], wds[idx], np.ones(len(idx))], axis=1)

    X, y = design(tr), accuracies[tr]
    sol, *_ = np.linalg.lstsq(X, y, rcond=None)
    coeffs = EtaCoefficients(beta1=float(sol[0]), beta2=float(sol[1]),
                             phi=float(sol[2]))

    def r2(idx):
        if len(idx) == 0:
            return float("nan")
        pred = design(idx) @ sol
        resid = accuracies[idx] - pred
        tot = accuracies[idx] - accuracies[idx].mean()
        denom = float((tot ** 2).sum())
        if denom == 0.0:
            return 1.0 if float((resid ** 2).sum()) < 1e-12 else 0.0
        return 1.0 - float((resid ** 2).sum()) / denom

    return coeffs, r2(tr), r2(te)
