"""repro.core.population — million-device fleets with O(K) round state.

Both engines stack the whole fleet into one worker-axis pytree, capping
`num_workers` at a few hundred by memory. Production FL (and the DSL
survey's massive-fleet regime, arXiv:2403.20188) instead registers a
huge population P and activates a small cohort K per round. This module
is that split:

  PopulationTable  per-device persistent scalars, struct-of-arrays over
                   P: the physical-layer state (fading gains, pathloss
                   slot, last-known SNR, delivery age), the EF-residual
                   norm, the last observed Eq.-5 score, and last-seen /
                   last-evolved round markers. Nine (P,) vectors — 36
                   bytes per device, 36 MB at P=1M — and NEVER an
                   O(P) model pytree.
  sample_cohort    a jitted K-subset sampler (Gumbel-top-k: adding
                   i.i.d. Gumbel noise to logits and taking the top K
                   is an exact without-replacement weighted draw) with
                   three policies: `uniform`, `score_weighted` (prefer
                   devices whose last Eq.-5 theta was low), `snr_aware`
                   (prefer devices whose last-known received SNR is
                   high).
  gather_phy       cohort rows -> a K-slot PhyState for the engine,
                   catching up idle rounds lazily: Δ rounds of
                   Gauss-Markov fading collapse into ONE closed-form
                   draw (`phy.lazy_fading_coeffs`), and the delivery
                   age advances by the idle-round count. O(K) work per
                   round no matter how large P is.
  scatter_round    post-round cohort state back into the table.

Key discipline: everything here draws from `fold_in(round_key,
POP_SALT)` — a stream the legacy engines never touch — and the
degenerate configuration (population == cohort_size, uniform policy)
selects the identity cohort with lag-0 catch-ups guarded by
`jnp.where`, so such runs are bit-identical to the legacy full-fleet
route (tests/test_population.py pins this on the golden configs).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.comm import phy as comm_phy
from repro.comm.budget import CommConfig
from repro.comm.phy import PhyState

Array = jax.Array

POP_SALT = 0xC0   # population scheduling key = fold_in(round_key, salt):
#                   sampling + lazy catch-up draws live on their own
#                   stream, leaving the engines' legacy splits untouched

COHORT_POLICIES = ("uniform", "score_weighted", "snr_aware")

_SNR_TEMP_DB = 10.0   # snr_aware softness: +10 dB last-known SNR ~ e x odds


class PopulationTable(NamedTuple):
    """Struct-of-arrays registry of P devices — O(P) scalars only.

    `phy` is a population-sized PhyState: the same five per-device
    channel columns the engines carry for the cohort, resident here for
    everyone (pathloss is the device's static slot in the P-wide
    profile; h/snr/age are its last participating state). `score` is
    the last observed Eq.-5 theta, `ef_norm` the L2 norm of the
    device's uplink error-feedback residual when it left the cohort.
    `last_seen` / `last_evolved` are round indices (-1 = never): the
    round the device last held a cohort seat, and the round whose
    in-round fading evolution produced the stored h."""
    phy: PhyState        # five (P,) columns (h_re/h_im/pathloss/snr/age)
    ef_norm: Array       # (P,) f32 uplink EF-residual L2 norm at exit
    score: Array         # (P,) f32 last observed Eq.-5 theta
    last_seen: Array     # (P,) i32 last participation round (-1 = never)
    last_evolved: Array  # (P,) i32 round of the stored fading state


def init_table(comm: CommConfig, population: int) -> PopulationTable:
    """Fresh registry: unit-gain channels over the P-wide pathloss
    profile (the same `phy.init_state` the engines use, so the
    degenerate P == K table starts bit-identical to the legacy
    per-worker state), zero scores/norms, nothing seen yet."""
    z = jnp.zeros((population,), jnp.float32)
    neg1 = jnp.full((population,), -1, jnp.int32)
    return PopulationTable(phy=comm_phy.init_state(comm, population),
                           ef_norm=z, score=z,
                           last_seen=neg1, last_evolved=neg1)


def table_specs(population: int) -> PopulationTable:
    """ShapeDtypeStruct stand-ins for one table (dry-run sharding/
    pricing on the mesh path without allocating P-sized buffers)."""
    f32 = lambda: jax.ShapeDtypeStruct((population,), jnp.float32)
    i32 = lambda: jax.ShapeDtypeStruct((population,), jnp.int32)
    return PopulationTable(
        phy=PhyState(h_re=f32(), h_im=f32(), pathloss_db=f32(),
                     snr_db=f32(), age=i32()),
        ef_norm=f32(), score=f32(), last_seen=i32(), last_evolved=i32())


def table_bytes(table: PopulationTable) -> int:
    """Total registry footprint in bytes (the O(P)-scalar budget)."""
    return int(sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(table)))


# ---------------------------------------------------------------------------
# cohort sampling
# ---------------------------------------------------------------------------

def _policy_logits(table: PopulationTable, policy: str) -> Array:
    """Per-device selection logits. Rankings use the table's LAST-KNOWN
    state (a device's score/SNR is as stale as its last participation)
    — the scheduler cannot observe devices it never talks to."""
    if policy == "uniform":
        return jnp.zeros_like(table.score)
    if policy == "score_weighted":
        # lower Eq.-5 theta = better device -> higher logit. Standardize
        # over the seen sub-population; never-seen devices sit at the
        # seen mean (round 0: all-unseen degrades to uniform).
        seen = (table.last_seen >= 0).astype(jnp.float32)
        n = jnp.maximum(seen.sum(), 1.0)
        mean = (table.score * seen).sum() / n
        var = (((table.score - mean) ** 2) * seen).sum() / n
        z = (table.score - mean) / (jnp.sqrt(var) + 1e-6)
        return jnp.where(seen > 0, -z, 0.0)
    if policy == "snr_aware":
        return table.phy.snr_db / _SNR_TEMP_DB
    raise ValueError(f"unknown cohort policy {policy!r} "
                     f"(choose from {COHORT_POLICIES})")


def sample_cohort(table: PopulationTable, cohort_size: int, policy: str,
                  key: Array) -> Array:
    """Draw K distinct device ids from the P-device registry.

    Gumbel-top-k: top_k(logits + Gumbel noise) is an exact
    without-replacement draw from the softmax of the logits, and it is
    jittable at P = 1M (one (P,) noise draw + one top_k). The
    degenerate full-fleet case — population == cohort_size under the
    uniform policy — returns the identity cohort with NO draw, the
    anchor of the bit-identity guarantee with the legacy engines."""
    P = table.score.shape[0]
    if policy == "uniform" and P == cohort_size:
        return jnp.arange(cohort_size, dtype=jnp.int32)
    noisy = _policy_logits(table, policy) + jax.random.gumbel(
        key, (P,), jnp.float32)
    _, idx = jax.lax.top_k(noisy, cohort_size)
    return idx.astype(jnp.int32)


# ---------------------------------------------------------------------------
# gather (with lazy catch-up) / scatter
# ---------------------------------------------------------------------------

def gather_phy(comm: CommConfig, table: PopulationTable, idx: Array,
               round_idx: Array, key: Array) -> PhyState:
    """Cohort rows -> the K-slot PhyState entering round `round_idx`.

    A stored row was last refreshed by round `last_evolved`'s in-round
    evolution; entering round t the legacy engine would have evolved it
    lag = t - 1 - last_evolved more times. The Gauss-Markov recursion
    telescopes, so those lag idle rounds collapse into one draw

        h <- rho^lag h + sqrt(1 - rho^(2 lag)) CN(0, 1)

    (`phy.lazy_fading_coeffs`) with a per-DEVICE key (fold_in by device
    id), making the marginal exact at O(K) cost. The delivery age
    advances by the idle-round count the same way. lag == 0 rows pass
    through a `jnp.where` guard bitwise untouched — the degenerate
    full-fleet cohort re-enters exactly the state it scattered."""
    p = jax.tree.map(lambda x: x[idx], table.phy)
    age = p.age + (round_idx - 1 - table.last_seen[idx])
    if comm.fading == "none":
        return p._replace(age=age)
    lag = round_idx - 1 - table.last_evolved[idx]
    rho_d, innov = comm_phy.lazy_fading_coeffs(comm, lag)
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(key, idx)
    n = jax.vmap(lambda k: jax.random.normal(k, (2,), jnp.float32))(keys)
    std = jnp.sqrt(0.5).astype(jnp.float32)
    h_re = rho_d * p.h_re + innov * std * n[:, 0]
    h_im = rho_d * p.h_im + innov * std * n[:, 1]
    fresh = lag > 0
    h_re = jnp.where(fresh, h_re, p.h_re)
    h_im = jnp.where(fresh, h_im, p.h_im)
    snr = jnp.where(fresh, comm_phy.instantaneous_snr_db(
        comm, h_re, h_im, p.pathloss_db), p.snr_db)
    return PhyState(h_re=h_re, h_im=h_im, pathloss_db=p.pathloss_db,
                    snr_db=snr, age=age)


@functools.partial(jax.jit,
                   static_argnames=("comm", "cohort_size", "policy"))
def schedule(table: PopulationTable, round_idx: Array, key: Array, *,
             comm: CommConfig, cohort_size: int, policy: str
             ) -> tuple[Array, PhyState]:
    """One round of population scheduling: sample the K-cohort, gather
    its channel rows with lazy catch-up. Returns (device ids, PhyState
    for the engine's worker axis)."""
    skey, ckey = jax.random.split(key)
    idx = sample_cohort(table, cohort_size, policy, skey)
    return idx, gather_phy(comm, table, idx, round_idx, ckey)


def residual_norms(residual) -> Array:
    """Per-slot L2 norms of the stacked uplink EF residual — the O(1)-
    per-device summary the table keeps in place of the O(n) residual."""
    total = None
    for x in jax.tree.leaves(residual):
        sq = (x.astype(jnp.float32) ** 2).sum(
            axis=tuple(range(1, x.ndim)))
        total = sq if total is None else total + sq
    return jnp.sqrt(total)


@jax.jit
def scatter_round(table: PopulationTable, idx: Array, phy: PhyState,
                  theta: Array, ef_norm: Array, round_idx: Array
                  ) -> PopulationTable:
    """Write the cohort's post-round state back: the advanced channel
    rows (post-evolve, post-advance_age), the round's Eq.-5 scores, the
    EF-residual norms, and both round markers. Pathloss is static (the
    device's registry slot) and never rewritten. Sampling is without
    replacement, so the scatter indices are unique."""
    stamp = jnp.broadcast_to(round_idx.astype(jnp.int32), idx.shape)
    up = lambda col, v: col.at[idx].set(v)
    return PopulationTable(
        phy=PhyState(h_re=up(table.phy.h_re, phy.h_re),
                     h_im=up(table.phy.h_im, phy.h_im),
                     pathloss_db=table.phy.pathloss_db,
                     snr_db=up(table.phy.snr_db, phy.snr_db),
                     age=up(table.phy.age, phy.age)),
        ef_norm=up(table.ef_norm, ef_norm),
        score=up(table.score, theta),
        last_seen=up(table.last_seen, stamp),
        last_evolved=up(table.last_evolved, stamp))
