"""PSO-hybrid local update of M-DSL (paper §III-B/C, Eqs. 8-10).

Each worker i maintains, besides its parameters w_i, a velocity v_i, its
best-so-far parameters w_i^l (Eq. 9) and a view of the global best w^g
(Eq. 10). One local update step is (Eq. 8, vector form — see DESIGN.md
§1 for why the vector form is the faithful reading):

    v_{i,t+1} = c0 * v_{i,t}
              + c1 * (w_i^l - w_{i,t})
              + c2 * (w^g  - w_{i,t})
              - lr * grad F(w_{i,t}, D_i)
    w_{i,t+1} = w_{i,t} + v_{i,t+1}

with c0 ~ U(0,1), c1, c2 ~ N(0,1) re-sampled each communication round
(paper §V-A). All state lives in parameter-pytree space, so the update is
model-agnostic; the fused Pallas kernel in `repro.kernels.pso_update`
implements the same arithmetic for the flat hot path.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


class PsoCoefficients(NamedTuple):
    c0: Array  # inertia,   U(0,1)
    c1: Array  # cognitive, N(0,1)
    c2: Array  # social,    N(0,1)


class PsoHyperParams(NamedTuple):
    learning_rate: float = 0.01
    lr_decay: float = 0.5          # attenuation gamma (paper §V-A)
    lr_decay_every: int = 10       # rounds between decays
    velocity_clip: float = 0.0     # 0 = faithful paper (no clip); >0 clips |v|


class WorkerState(NamedTuple):
    """Per-worker swarm state. Every leaf mirrors the param pytree except
    the scalar losses."""
    params: PyTree
    velocity: PyTree
    best_params: PyTree     # w_i^l  (Eq. 9)
    best_loss: Array        # F at w_i^l
    prev_loss: Array        # F_{i,t-1}, for the Eq. 9 indicator


class GlobalBest(NamedTuple):
    """Shared global-best view (Eq. 10)."""
    params: PyTree          # w^g-bar
    loss: Array             # F at w^g-bar
    prev_loss: Array        # F_{t-1}, for the Eq. 10 indicator


def sample_coefficients(key: Array) -> PsoCoefficients:
    """c0 ~ U(0,1); c1, c2 ~ N(0,1) (paper §V-A)."""
    k0, k1, k2 = jax.random.split(key, 3)
    return PsoCoefficients(
        c0=jax.random.uniform(k0, ()),
        c1=jax.random.normal(k1, ()),
        c2=jax.random.normal(k2, ()),
    )


def init_worker_state(params: PyTree) -> WorkerState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    inf = jnp.asarray(jnp.inf, jnp.float32)
    return WorkerState(params=params, velocity=zeros, best_params=params,
                       best_loss=inf, prev_loss=inf)


def init_global_best(params: PyTree) -> GlobalBest:
    inf = jnp.asarray(jnp.inf, jnp.float32)
    return GlobalBest(params=params, loss=inf, prev_loss=inf)


def _select_tree(take_new: Array, new: PyTree, old: PyTree) -> PyTree:
    return jax.tree.map(lambda n, o: jnp.where(take_new, n, o), new, old)


def update_local_best(state: WorkerState, loss: Array) -> WorkerState:
    """Eq. 9: w_i^l <- argmin_{w in {w^l, w_i,t}} F."""
    improved = loss < state.best_loss
    return state._replace(
        best_params=_select_tree(improved, state.params, state.best_params),
        best_loss=jnp.where(improved, loss, state.best_loss),
        prev_loss=loss,
    )


def update_global_best(gbest: GlobalBest, params: PyTree,
                       loss: Array) -> GlobalBest:
    """Eq. 10: w^g <- argmin_{w in {w^g, w_t}} F."""
    improved = loss < gbest.loss
    return GlobalBest(
        params=_select_tree(improved, params, gbest.params),
        loss=jnp.where(improved, loss, gbest.loss),
        prev_loss=loss,
    )


def pso_step(state: WorkerState, gbest_params: PyTree, grads: PyTree,
             coeffs: PsoCoefficients, lr: Array,
             hp: PsoHyperParams = PsoHyperParams()) -> WorkerState:
    """One Eq.-8 update. Returns state with new params & velocity."""

    def leaf(w, v, wl, wg, g):
        v_new = (coeffs.c0 * v + coeffs.c1 * (wl - w) + coeffs.c2 * (wg - w)
                 - lr * g)
        if hp.velocity_clip > 0.0:
            v_new = jnp.clip(v_new, -hp.velocity_clip, hp.velocity_clip)
        return v_new.astype(w.dtype)

    v_next = jax.tree.map(leaf, state.params, state.velocity,
                          state.best_params, gbest_params, grads)
    w_next = jax.tree.map(jnp.add, state.params, v_next)
    return state._replace(params=w_next, velocity=v_next)


def sgd_step(params: PyTree, grads: PyTree, lr: Array) -> PyTree:
    """Plain SGD step (FedAvg baseline local update). Preserves each
    leaf's dtype (bf16 swarm state on the mesh)."""
    return jax.tree.map(lambda w, g: (w - lr * g).astype(w.dtype),
                        params, grads)


def decayed_lr(hp: PsoHyperParams, round_idx: Array) -> Array:
    """Attenuated learning rate alpha_init * gamma^(t // k) (paper §V-A)."""
    exponent = jnp.asarray(round_idx // hp.lr_decay_every, jnp.float32)
    return hp.learning_rate * (hp.lr_decay ** exponent)
