"""Composable round engine: Algorithm 1 as a pipeline of pluggable stages.

One communication round of every engine in this repo factors into the
same six stages over stacked-worker pytrees (leading dim C):

  LocalUpdate    engine-specific (PSO-hybrid epochs, mesh SGD steps,
                 FedAvg deltas) — supplied by the engine, see
                 `core/mdsl.py` / `core/swarm_dist.py`
  ScoreSelect    Eq. 5 trade-off scores + Eq. 6 adaptive-threshold
                 selection (`score_select`; fedavg = all-ones, dsl =
                 single best)
  Uplink         per-worker delta compression with error feedback and
                 per-worker wire-tier resolution (`uplink`; N tiers
                 ranked by Eq.-5 score or instantaneous SNR)
  Aggregate      phy link + Eq. 7 (`comm.channel.receive` over the
                 evolved `comm.phy.PhyState`: delivery, distortion,
                 then masked mean / coordinate-wise median / trimmed
                 mean)
  Downlink       the PS broadcast of the global update, optionally
                 quantized with PS-side error feedback (`downlink`)
  BestTracking   Eq. 9/10 local/global best refresh (`track_local_best`
                 / `track_global_best`)

`RoundPipeline` bundles the stages with the static round configuration;
engines instantiate it once per (algorithm, comm, C) and call
`select` / `wire` / `telemetry`. The Eq.-7-through-the-wire block
(compress_with_ef -> select_residual -> channel.receive -> downlink ->
round_record) lives ONLY here — `wire_round` — so every comm feature
(robust aggregation, downlink compression, adaptive bits, Rayleigh
fading + airtime/energy accounting, future async stages) lands once
and reaches the paper engine, the mesh engine, and the FedAvg baseline
simultaneously.

All stages are pure `(carry, ctx) -> (carry, telemetry)`-style functions
of stacked pytrees: no Python state, jit/vmap/spmd-safe (the mesh engine
passes `axis_name` so per-worker vmaps keep their sharding
constraints).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.comm import budget as comm_budget
from repro.comm import channel as comm_channel
from repro.comm import compress as comm_compress
from repro.comm import phy as comm_phy
from repro.comm import straggler as comm_straggler
from repro.comm.budget import CommConfig
from repro.comm.phy import PhyState
from repro.core import selection
from repro.core.selection import SelectionState
from repro.obs.trace import stage_span

Array = jax.Array
PyTree = Any

_DOWNLINK_SALT = 0xD0  # dkey = fold_in(qkey, salt): keeps the engines'
#                        key-split structure (and goldens) unchanged


class RoundTelemetry(NamedTuple):
    """Unified per-round telemetry — the superset of the pre-refactor
    RoundMetrics (paper path) and RoundInfo (mesh path), carried by all
    engines so no path drops wire accounting again."""
    losses: Array             # (C,) F_{i,t+1} on D_g
    theta: Array              # (C,) Eq.-5 scores
    mask: Array               # (C,) Eq.-6 selection indicator
    global_loss: Array        # () F(w_{t+1}; D_g)
    selected_count: Array     # () sum_i s_i
    uploaded_params: Array    # () n * sum_i s_i (paper §IV-C legacy unit)
    bytes_up: Array           # () wire bytes transmitted this round
    bytes_down: Array         # () broadcast bytes (downlink-compressed)
    delivered: Array          # () uploads surviving the channel
    compression_ratio: Array  # () dense payload / mean uplink payload
    airtime_s: Array          # () uplink airtime (SNR->rate, comm.phy)
    energy_j: Array           # () transmit energy = tx_power * airtime
    mean_snr_db: Array        # () fleet-mean instantaneous received SNR
    # (K,) int32 device ids seated this round by the population engine
    # (core/population.py); None on legacy full-fleet runs, so existing
    # engines/goldens never see the field
    cohort: Any = None
    # straggler engine scalars (comm.straggler); None unless
    # round_deadline_s is set, so legacy configs never see them
    late: Any = None          # () selected uploads past the deadline
    drained: Any = None       # () buffered deltas folded in this round
    buffered: Any = None      # () buffer occupancy after the round
    held: Any = None          # () 1.0 on a quorum-hold round
    # () workers that actually transmitted (selected minus crashed);
    # None unless fault injection is on
    transmitted: Any = None

    # pre-refactor field names, kept so existing consumers read the
    # unified record unchanged
    @property
    def eval_losses(self) -> Array:
        return self.losses

    @property
    def delivered_count(self) -> Array:
        return self.delivered


class WireOutcome(NamedTuple):
    """Result of the Uplink -> Aggregate -> Downlink stage chain."""
    global_params: PyTree     # the broadcast w_{t+1} workers will see
    residual: PyTree          # (C, ...) advanced uplink EF state
    ps_residual: PyTree       # PS-side downlink EF state
    mask_eff: Array           # (C,) post-channel survivor mask
    record: comm_budget.CommRecord
    phy: Any = None           # advanced PhyState (None for phy-less calls)
    buffer: Any = None        # advanced StragglerBuffer (None: deadline off)
    straggler: Any = None     # StragglerStats (None: deadline off)
    transmitted: Any = None   # () transmitting-worker count (None: no faults)


# ---------------------------------------------------------------------------
# ScoreSelect stage
# ---------------------------------------------------------------------------

def score_select(algorithm: str, losses: Array, eta: Array, tau: float,
                 prev_theta_mean: Array) -> tuple[Array, Array, Array]:
    """Eq. 5 scores + the per-algorithm selection rule.

    mdsl scores theta = tau*F + (1-tau)*eta; the baselines score on F
    alone. fedavg selects everyone, dsl the single best worker,
    multi_dsl/mdsl the Eq.-6 adaptive threshold (with the >=1
    fallback). Returns (theta, mask, new_theta_mean)."""
    if algorithm == "mdsl":
        theta = selection.tradeoff_scores(losses, eta, tau)
    else:
        theta = losses
    if algorithm == "fedavg":
        return theta, jnp.ones_like(theta), theta.mean()
    if algorithm == "dsl":
        mask = jax.nn.one_hot(jnp.argmin(theta), theta.shape[0],
                              dtype=jnp.float32)
        return theta, mask, theta.mean()
    mask, sel = selection.select_workers(
        theta, SelectionState(prev_theta_mean=prev_theta_mean))
    return theta, mask, sel.prev_theta_mean


# ---------------------------------------------------------------------------
# Uplink stage
# ---------------------------------------------------------------------------

def tier_masks(comm: CommConfig, theta: Array, snr_db: Array = None
               ) -> tuple[tuple[CommConfig, ...], Array]:
    """Per-worker wire-config resolution: with `adaptive_bits`, the PS
    splits the fleet over the `uplink_tiers` degradation chain by rank —
    Eq.-5 score (`tier_rank="score"`, lower theta = better) or
    instantaneous SNR (`tier_rank="snr"`, higher SNR = more bits; falls
    back to score when no PhyState is threaded). Tier t covers ranks
    [ceil(C t / T), ceil(C (t+1) / T)), so with T=2 the better
    ceil(C/2) workers keep the base config — exactly the legacy split.
    Returns (tiers, tier_idx) where tier_idx is the (C,) int32 tier
    index (None when uniform)."""
    tiers = comm_budget.uplink_tiers(comm)
    if len(tiers) == 1:
        return tiers, None
    C = theta.shape[0]
    key_arr = (-snr_db if comm.tier_rank == "snr" and snr_db is not None
               else theta)
    rank = jnp.argsort(jnp.argsort(key_arr))  # 0 = best
    T = len(tiers)
    tier_idx = jnp.zeros((C,), jnp.int32)
    for t in range(1, T):
        tier_idx = tier_idx + (rank >= -(-C * t // T)).astype(jnp.int32)
    return tiers, tier_idx


def uplink(comm: CommConfig, delta: PyTree, residual: PyTree, theta: Array,
           mask: Array, key: Array, *, snr_db: Array = None,
           axis_name: Any = None) -> tuple[PyTree, PyTree, Array]:
    """Uplink stage: compress each worker's delta (+ error feedback),
    resolving per-worker wire tiers. Residuals advance only for workers
    whose upload was attempted (Eq.-6 selected). Returns
    (wire, new_residual, tier_idx)."""
    C = theta.shape[0]
    keys = jax.random.split(key, C)

    def run(tcfg: CommConfig):
        return jax.vmap(
            functools.partial(comm_compress.compress_with_ef, tcfg),
            spmd_axis_name=axis_name)(delta, residual, keys)

    tiers, tier_idx = tier_masks(comm, theta, snr_db)
    wire, new_res = run(tiers[0])
    for t in range(1, len(tiers)):
        w_t, r_t = run(tiers[t])

        def pick(a, b, t=t):
            return jax.tree.map(
                lambda x, y: jnp.where(
                    (tier_idx == t).reshape((-1,) + (1,) * (x.ndim - 1)),
                    y, x),
                a, b)

        wire, new_res = pick(wire, w_t), pick(new_res, r_t)
    new_residual = comm_compress.select_residual(mask, new_res, residual)
    return wire, new_residual, tier_idx


def uplink_packed(comm: CommConfig, delta: PyTree, residual: PyTree,
                  mask: Array, key: Array, *, axis_name: Any = None
                  ) -> tuple["comm_compress.PackedWire", PyTree]:
    """Uplink stage, fused wire format: one vmapped
    quantize+pack+EF-update kernel pass per worker
    (`compress_with_ef_packed`), emitting stacked packed payloads
    instead of dense decodes. Same per-worker key split as `uplink`, so
    payload bits match the legacy route exactly. Single-tier only (the
    packed route is gated off under adaptive_bits)."""
    C = mask.shape[0]
    keys = jax.random.split(key, C)
    wire, new_res = jax.vmap(
        functools.partial(comm_compress.compress_with_ef_packed, comm),
        spmd_axis_name=axis_name)(delta, residual, keys)
    new_residual = comm_compress.select_residual(mask, new_res, residual)
    return wire, new_residual


# ---------------------------------------------------------------------------
# Downlink stage
# ---------------------------------------------------------------------------

def downlink(comm: CommConfig, agg_params: PyTree, prev_broadcast: PyTree,
             ps_residual: PyTree, key: Array) -> tuple[PyTree, PyTree]:
    """Downlink stage: broadcast the global update. With a non-identity
    `downlink_compressor`, the PS quantizes the global delta with its
    own error-feedback residual and workers decode broadcast = w_t +
    decoded delta; the EF telescopes so the broadcast trajectory tracks
    the exact aggregate (same mechanism as the uplink, one residual,
    PS-side). Returns (broadcast_params, new_ps_residual)."""
    if comm.downlink_compressor == "identity":
        return agg_params, ps_residual
    dcfg = comm_budget.downlink_config(comm)
    delta = jax.tree.map(lambda a, b: (a - b).astype(jnp.float32),
                         agg_params, prev_broadcast)
    wire, new_res = comm_compress.compress_with_ef(dcfg, delta, ps_residual,
                                                   key)
    bcast = jax.tree.map(lambda g, w: (g + w).astype(g.dtype),
                         prev_broadcast, wire)
    return bcast, new_res


def init_ps_residual(params: PyTree) -> PyTree:
    """Zero PS-side downlink error-feedback state (unstacked, f32)."""
    return comm_compress.init_residual(params)


# ---------------------------------------------------------------------------
# the one Eq.-7-through-the-wire block
# ---------------------------------------------------------------------------

def wire_round(comm: CommConfig, *, delta: PyTree, theta: Array,
               mask: Array, global_params: PyTree, residual: PyTree,
               ps_residual: PyTree, qkey: Array, wkey: Array,
               num_workers: int, phy: PhyState = None,
               buffer: Any = None, round_idx: Array = None,
               axis_name: Any = None,
               uplink_fn: Callable = uplink,
               aggregate_fn: Callable = comm_channel.receive,
               downlink_fn: Callable = downlink) -> WireOutcome:
    """Uplink -> Aggregate -> Downlink with byte/airtime accounting: the
    single home of the wire pipeline shared by every engine. Stage
    functions are injectable (custom aggregation rules plug in here).

    `phy` is the per-worker channel state (comm.phy.PhyState): the
    fading gains evolve first (block fading — one draw per round, on
    the fold_in(wkey, PHY_SALT) stream so the legacy key structure is
    untouched), the round then runs against the evolved instantaneous
    SNRs (tier ranking, outage, distortion, airtime/energy), and the
    advanced state (with refreshed delivery ages) returns in the
    outcome. With phy=None the wire prices airtime at the shared
    cfg.snr_db and no per-worker SNR effects apply.

    `buffer`/`round_idx` feed the straggler engine (comm.straggler):
    with `round_deadline_s` set, a Straggle stage between Uplink and
    Aggregate derives deadline misses from each upload's airtime, parks
    late deltas in `buffer`, drains stale ones with the FedBuff
    discount, and holds w_t bitwise when fewer than `quorum` deltas are
    available. With `fault_prob` > 0 a deterministic churn schedule
    (keyed off `round_idx`) deselects crashed workers before the
    uplink. Both default to off, leaving the legacy route untouched."""
    straggler_mode = comm_straggler.active(comm)
    if straggler_mode and (uplink_fn is not uplink
                           or aggregate_fn is not comm_channel.receive):
        raise ValueError(
            "round_deadline_s replaces the Aggregate stage with the "
            "straggler engine; it cannot compose with injected "
            "uplink/aggregate stage functions")
    if straggler_mode and buffer is None:
        raise ValueError(
            "straggler mode needs the parked-delta state: init the "
            "engine with comm.straggler.init_buffer and thread it "
            "through wire_round(buffer=...)")
    transmitted = None
    if comm_straggler.fault_mode(comm):
        if round_idx is None:
            raise ValueError("fault injection (fault_prob > 0) needs the "
                             "round index: pass wire_round(round_idx=...)")
        # crashed workers transmit nothing: no bytes, no airtime, no EF
        # advance — the Eq.-6 selection stays what the scores chose, the
        # wire just never hears from them
        alive = comm_straggler.alive_mask(comm, round_idx, mask.shape[0])
        mask = mask * alive
        transmitted = mask.sum()
    if phy is not None:
        phy = comm_phy.evolve(comm, phy,
                              jax.random.fold_in(wkey, comm_phy.PHY_SALT))
        snr_db = phy.snr_db
    else:
        snr_db = None
    # Fused wire-format route: when the Uplink/Aggregate stages are the
    # defaults (an injected stage must see the legacy dense wire) and
    # the config qualifies (quantized single-tier uplink, no AWGN, f32
    # leaves), the round runs quantize+pack+EF and dequant+masked-
    # aggregate as the two fused kernel passes instead of the dense
    # compress -> decode -> aggregate chain. Payload bits, survivor
    # masks, aggregates, and byte accounting are bit-identical to the
    # legacy route; the EF residual agrees up to XLA FMA contraction
    # (tests/test_wire_kernels.py). The decision is static under jit.
    packed_route = (uplink_fn is uplink
                    and aggregate_fn is comm_channel.receive
                    and comm_compress.packed_wire_eligible(comm, delta))
    # stage_span is a shared nullcontext unless an obs tracer is
    # installed; spans inside a jitted round fire at trace time
    sstats = None
    if packed_route:
        with stage_span("Uplink"):
            wire, residual = uplink_packed(comm, delta, residual, mask,
                                           qkey, axis_name=axis_name)
            tier_idx = None
        with stage_span("Aggregate"):
            agg_params, mask_eff = comm_channel.receive_packed(
                comm, global_params, wire, mask, wkey, snr_db=snr_db)
    elif straggler_mode:
        # the straggler route always runs the dense uplink: parking a
        # late delta needs the individual reconstruction
        # (compress.packed_wire_eligible gates the fused route off)
        with stage_span("Uplink"):
            wire, residual, tier_idx = uplink_fn(comm, delta, residual,
                                                 theta, mask, qkey,
                                                 snr_db=snr_db,
                                                 axis_name=axis_name)
        with stage_span("Straggle"):
            late = comm_straggler.late_mask(comm, global_params, mask,
                                            snr_db=snr_db,
                                            tier_idx=tier_idx)
        with stage_span("Aggregate"):
            agg_params, mask_eff, buffer, sstats = (
                comm_straggler.aggregate_and_drain(
                    comm, global_params, wire, mask, late, wkey, snr_db,
                    buffer))
    else:
        with stage_span("Uplink"):
            wire, residual, tier_idx = uplink_fn(comm, delta, residual,
                                                 theta, mask, qkey,
                                                 snr_db=snr_db,
                                                 axis_name=axis_name)
        with stage_span("Aggregate"):
            agg_params, mask_eff = aggregate_fn(comm, global_params, wire,
                                                mask, wkey, snr_db=snr_db)
    with stage_span("Downlink"):
        bcast, ps_res_new = downlink_fn(comm, agg_params, global_params,
                                        ps_residual,
                                        jax.random.fold_in(
                                            qkey, _DOWNLINK_SALT))
    if straggler_mode:
        # quorum hold: the PS broadcasts w_t unchanged and its downlink
        # EF state freezes — otherwise a compressed downlink would still
        # flush its residual through a zero aggregate
        held = sstats.held > 0
        bcast = jax.tree.map(lambda g, b: jnp.where(held, g, b),
                             global_params, bcast)
        ps_residual = jax.tree.map(lambda o, n: jnp.where(held, o, n),
                                   ps_residual, ps_res_new)
    else:
        ps_residual = ps_res_new
    rec = comm_budget.round_record(comm, global_params, num_workers, mask,
                                   mask_eff, tier_idx=tier_idx,
                                   snr_db=snr_db)
    if phy is not None:
        phy = comm_phy.advance_age(
            phy, mask_eff,
            buffered=(buffer.age if straggler_mode else None))
    return WireOutcome(global_params=bcast, residual=residual,
                       ps_residual=ps_residual, mask_eff=mask_eff,
                       record=rec, phy=phy, buffer=buffer,
                       straggler=sstats, transmitted=transmitted)


# ---------------------------------------------------------------------------
# BestTracking stage (Eqs. 9/10, stacked form used by the mesh engine;
# the paper engine keeps its WorkerState-shaped pso.update_*_best)
# ---------------------------------------------------------------------------

def track_local_best(best_params: PyTree, best_loss: Array, params: PyTree,
                     losses: Array) -> tuple[PyTree, Array]:
    """Eq. 9 over stacked workers: keep each worker's best-F params."""
    improved = losses < best_loss

    def leaf(n, o):
        c = improved.reshape((-1,) + (1,) * (n.ndim - 1))
        return jnp.where(c, n, o)

    return (jax.tree.map(leaf, params, best_params),
            jnp.where(improved, losses, best_loss))


def track_global_best(gbest_params: PyTree, gbest_loss: Array,
                      params: PyTree, loss: Array
                      ) -> tuple[PyTree, Array]:
    """Eq. 10: keep the best global model seen so far."""
    improved = loss < gbest_loss
    return (jax.tree.map(lambda n, o: jnp.where(improved, n, o), params,
                         gbest_params),
            jnp.minimum(loss, gbest_loss))


# ---------------------------------------------------------------------------
# shared LocalUpdate helper
# ---------------------------------------------------------------------------

def accumulated_grad(grad_fn: Callable, params: PyTree, batch: PyTree,
                     microbatches: int) -> PyTree:
    """Gradient of one local batch, optionally accumulated over
    microbatch chunks (f32 accumulator) to bound activation memory.
    `grad_fn` is a jax.value_and_grad of the loss."""
    if microbatches <= 1:
        _, g = grad_fn(params, batch)
        return g
    k = microbatches
    mbs = jax.tree.map(
        lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch)

    def acc(g_sum, mb):
        _, g = grad_fn(params, mb)
        return jax.tree.map(
            lambda s, gg: s + gg.astype(jnp.float32), g_sum, g), None

    zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
    g, _ = jax.lax.scan(acc, zeros, mbs)
    return jax.tree.map(lambda gg, pp: (gg / k).astype(pp.dtype), g, params)


# ---------------------------------------------------------------------------
# the pipeline object
# ---------------------------------------------------------------------------

class RoundPipeline(NamedTuple):
    """Static round configuration + the stage functions. Engines build
    one per (algorithm x comm x fleet) and run their round as

        theta, mask, mean = pipe.select(losses, eta, prev_mean)
        out = pipe.wire(delta=..., theta=theta, mask=mask, ...)
        tel = pipe.telemetry(losses=..., ..., outcome=out)

    keeping only their LocalUpdate / BestTracking stages local. Stage
    fields are swappable for new scenarios (e.g. a staleness-weighted
    aggregate_fn) without touching any engine."""
    algorithm: str
    comm: CommConfig
    num_workers: int
    tau: float = 0.9
    n_params: int = 0
    axis_name: Any = None             # mesh spmd vmap axis (None on CPU)
    score_select_fn: Callable = score_select
    uplink_fn: Callable = uplink
    aggregate_fn: Callable = comm_channel.receive
    downlink_fn: Callable = downlink

    def select(self, losses: Array, eta: Array, prev_theta_mean: Array
               ) -> tuple[Array, Array, Array]:
        with stage_span("ScoreSelect"):
            return self.score_select_fn(self.algorithm, losses, eta,
                                        self.tau, prev_theta_mean)

    def wire(self, *, delta: PyTree, theta: Array, mask: Array,
             global_params: PyTree, residual: PyTree, ps_residual: PyTree,
             qkey: Array, wkey: Array, phy: PhyState = None,
             buffer: Any = None, round_idx: Array = None) -> WireOutcome:
        return wire_round(self.comm, delta=delta, theta=theta, mask=mask,
                          global_params=global_params, residual=residual,
                          ps_residual=ps_residual, qkey=qkey, wkey=wkey,
                          num_workers=self.num_workers, phy=phy,
                          buffer=buffer, round_idx=round_idx,
                          axis_name=self.axis_name,
                          uplink_fn=self.uplink_fn,
                          aggregate_fn=self.aggregate_fn,
                          downlink_fn=self.downlink_fn)

    def telemetry(self, *, losses: Array, theta: Array, mask: Array,
                  global_loss: Array, outcome: WireOutcome
                  ) -> RoundTelemetry:
        rec = outcome.record
        tel = RoundTelemetry(
            losses=losses, theta=theta, mask=mask, global_loss=global_loss,
            selected_count=mask.sum(),
            uploaded_params=selection.uploaded_parameter_count(
                mask, self.n_params),
            bytes_up=rec.bytes_up, bytes_down=rec.bytes_down,
            delivered=rec.delivered,
            compression_ratio=rec.compression_ratio,
            airtime_s=rec.airtime_s, energy_j=rec.energy_j,
            mean_snr_db=rec.mean_snr_db)
        if outcome.straggler is not None:
            s = outcome.straggler
            tel = tel._replace(late=s.late, drained=s.drained,
                               buffered=s.buffered, held=s.held)
        if outcome.transmitted is not None:
            tel = tel._replace(transmitted=outcome.transmitted)
        return tel


def count_params(params: PyTree) -> int:
    """Total parameter count (static under jit)."""
    return int(sum(x.size for x in jax.tree.leaves(params)))
