"""Multi-worker selection mechanism (paper §III-C, Eqs. 4-7).

Per round t every worker computes the trade-off score (Eq. 5)

    theta_{i,t} = tau * F_{i,t} + (1 - tau) * eta_i

and the PS selects every worker satisfying (Eq. 6)

    theta_{i,t} <= mean_j theta_{j,t-1}

(the adaptive threshold is the previous round's population mean). The
objective (Eq. 4) is to maximize participation, so selection is not
top-k: *all* workers beating the threshold participate. The global model
advances by the mean parameter delta of the selected workers (Eq. 7):

    w_{t+1} = w_t + (1/|S|) * sum_{i in S} (w_{i,t+1} - w_{i,t})

If no worker beats the threshold (possible early or after a loss spike),
we fall back to selecting the single best-theta worker so the round is
never wasted — this matches vanilla DSL's single-best behavior as the
degenerate case and keeps Eq. 7 well-defined (|S| >= 1).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


class SelectionState(NamedTuple):
    """Carries the adaptive threshold between rounds."""
    prev_theta_mean: Array  # mean_j theta_{j,t-1}; +inf on round 0 (all selected)


def init_selection_state() -> SelectionState:
    return SelectionState(prev_theta_mean=jnp.asarray(jnp.inf, jnp.float32))


def tradeoff_scores(losses: Array, eta: Array, tau: float = 0.9) -> Array:
    """Eq. 5. losses: (C,) F_{i,t} on the shared eval set; eta: (C,)."""
    return tau * losses + (1.0 - tau) * eta


def select_workers(theta: Array, sel_state: SelectionState
                   ) -> tuple[Array, SelectionState]:
    """Eq. 6 with the >=1 fallback. Returns (mask (C,) f32, next state)."""
    mask = (theta <= sel_state.prev_theta_mean).astype(jnp.float32)
    # Fallback: if nobody qualifies, take the single best-theta worker.
    best = jax.nn.one_hot(jnp.argmin(theta), theta.shape[0],
                          dtype=jnp.float32)
    mask = jnp.where(mask.sum() > 0, mask, best)
    return mask, SelectionState(prev_theta_mean=theta.mean())


def aggregate_global(global_params: PyTree, worker_params: PyTree,
                     prev_worker_params: PyTree, mask: Array) -> PyTree:
    """Eq. 7: masked mean of per-worker deltas, applied to the global model.

    worker_params / prev_worker_params: pytrees whose leaves carry a
    leading worker dim C; mask: (C,). Lowers to one all-reduce when the
    worker dim is mesh-sharded.

    The engines now aggregate through `repro.comm.channel.receive`
    (compression + channel on the wire deltas); with the default
    CommConfig that path reduces to exactly this function, which remains
    the property-tested Eq.-7 reference.
    """
    denom = jnp.maximum(mask.sum(), 1.0)

    def leaf(g, w, w_prev):
        delta = w - w_prev
        m = mask.reshape((-1,) + (1,) * (delta.ndim - 1))
        return (g + (m * delta).sum(axis=0) / denom).astype(g.dtype)

    return jax.tree.map(leaf, global_params, worker_params,
                        prev_worker_params)


def uploaded_parameter_count(mask: Array, n_params: int) -> Array:
    """Comm cost of the round: n * sum_i s_{i,t} (paper §IV-C)."""
    return mask.sum() * n_params
