"""M-DSL as a mesh-distributed train step (production integration).

The paper's C edge workers map onto the mesh as data-parallel groups
(DESIGN.md §3): the swarm state carries a leading *spatial worker* dim W
sharded over `worker_axes`; each worker's replica is sharded over the
remaining axes (TP over "model", FSDP over "data" in fsdp mode). One
jitted `train_step` is one communication round:

    1. every worker takes `local_steps` SGD steps on its micro-batch
    2. Eq. 8 PSO displacement (inertia + cognitive + social + SGD delta)
    3. every worker scores F_{i,t} on the shared eval batch (D_g)
    4. Eq. 5/6 selection against the previous round's mean score
    5. Eq. 7 through the repro.comm wire: per-worker delta compression
       (error-feedback residuals ride in the state), channel model
       (erasure / AWGN / Byzantine), masked delta-mean into the global
       model -> ONE all-reduce over worker_axes, with bytes-on-the-wire
       accounting in RoundInfo
    6. Eq. 9/10 local/global best refresh

vmap over the worker dim uses `spmd_axis_name=worker_axes` so internal
sharding constraints stay consistent with the worker sharding. With
W == 1 (fsdp mode: the time-multiplexed swarm) the vmap is skipped and
`temporal_workers` rounds can be scanned by the caller.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.comm import budget as comm_budget
from repro.comm import channel as comm_channel
from repro.comm import compress as comm_compress
from repro.comm.budget import CommConfig
from repro.core import pso, selection
from repro.core.pso import PsoHyperParams

Array = jax.Array
PyTree = Any


class DistSwarmConfig(NamedTuple):
    worker_axes: tuple[str, ...]    # () => single spatial worker (fsdp mode)
    num_spatial: int                # W
    local_steps: int = 1
    tau: float = 0.9
    hp: PsoHyperParams = PsoHyperParams(learning_rate=3e-3,
                                        velocity_clip=1.0)
    # grad-accumulation chunks per local step: caps per-device activation
    # memory at batch/microbatches (EXPERIMENTS.md §Perf iteration 2)
    microbatches: int = 1
    comm: CommConfig = CommConfig()  # uplink compression + channel


class DistSwarmState(NamedTuple):
    """All worker leaves stacked over W; global leaves unstacked."""
    params: PyTree            # (W, ...) worker models
    velocity: PyTree          # (W, ...)
    best_params: PyTree       # (W, ...) w^l (Eq. 9)
    best_loss: Array          # (W,)
    global_params: PyTree     # w_t (replicated over worker axes)
    gbest_params: PyTree      # w^g-bar (Eq. 10)
    gbest_loss: Array         # ()
    prev_theta_mean: Array    # () Eq. 6 threshold
    eta: Array                # (W,) non-iid degrees
    round_idx: Array          # ()
    residual: PyTree          # (W, ...) error-feedback state


class RoundInfo(NamedTuple):
    losses: Array             # (W,) F_{i,t+1} on D_g
    theta: Array              # (W,)
    mask: Array               # (W,)
    global_loss: Array        # ()
    bytes_up: Array           # () wire bytes transmitted this round
    delivered: Array          # () uploads surviving the channel


def init_state(global_params: PyTree, cfg: DistSwarmConfig,
               eta: Optional[Array] = None) -> DistSwarmState:
    W = cfg.num_spatial
    stack = lambda t: jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (W,) + x.shape), t)
    zeros = jax.tree.map(jnp.zeros_like, global_params)
    return DistSwarmState(
        params=stack(global_params),
        velocity=stack(zeros),
        best_params=stack(global_params),
        best_loss=jnp.full((W,), jnp.inf, jnp.float32),
        global_params=global_params,
        gbest_params=global_params,
        gbest_loss=jnp.asarray(jnp.inf, jnp.float32),
        prev_theta_mean=jnp.asarray(jnp.inf, jnp.float32),
        eta=jnp.zeros((W,), jnp.float32) if eta is None else eta,
        round_idx=jnp.zeros((), jnp.int32),
        residual=stack(jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), global_params)),
    )


def _spmd_axis_name(cfg: DistSwarmConfig):
    """vmap spmd_axis_name for the worker dim: None when the worker dim is
    not mesh-sharded (pure-CPU tests / temporal-only swarm with W>1)."""
    if len(cfg.worker_axes) == 0:
        return None
    if len(cfg.worker_axes) == 1:
        return cfg.worker_axes[0]
    return cfg.worker_axes


def build_train_step(loss_fn: Callable[[PyTree, dict], Array],
                     cfg: DistSwarmConfig
                     ) -> Callable[..., tuple[DistSwarmState, RoundInfo]]:
    """loss_fn(params, batch) -> scalar. Returns
    train_step(state, batch, eval_batch, key) where every leaf of `batch`
    has a leading worker dim W."""

    W = cfg.num_spatial
    grad_fn = jax.value_and_grad(loss_fn)

    def batch_grad(p, batch):
        """Gradient of the local batch, optionally accumulated over
        microbatch chunks (f32 accumulator) to bound activation memory."""
        k = cfg.microbatches
        if k <= 1:
            _, g = grad_fn(p, batch)
            return g
        mbs = jax.tree.map(
            lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch)

        def acc(g_sum, mb):
            _, g = grad_fn(p, mb)
            return jax.tree.map(
                lambda s, gg: s + gg.astype(jnp.float32), g_sum, g), None

        zeros = jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), p)
        g, _ = jax.lax.scan(acc, zeros, mbs)
        return jax.tree.map(lambda gg, pp: (gg / k).astype(pp.dtype), g, p)

    def local_round(params, velocity, best_params, gbest_params, batch,
                    coeffs=None, lr=None):
        """One worker: local SGD steps + Eq. 8 PSO displacement."""
        w0 = params

        def sgd(p, _):
            g = batch_grad(p, batch)
            return pso.sgd_step(p, g, lr), None

        trained, _ = jax.lax.scan(sgd, w0, None, length=cfg.local_steps)
        sgd_delta = jax.tree.map(lambda a, b: a - b, trained, w0)

        def leaf(w, v, wl, wg, d):
            v_new = (coeffs.c0 * v + coeffs.c1 * (wl - w)
                     + coeffs.c2 * (wg - w) + d)
            if cfg.hp.velocity_clip > 0:
                v_new = jnp.clip(v_new, -cfg.hp.velocity_clip,
                                 cfg.hp.velocity_clip)
            return v_new.astype(w.dtype)
        v_next = jax.tree.map(leaf, w0, velocity, best_params, gbest_params,
                              sgd_delta)
        p_next = jax.tree.map(jnp.add, w0, v_next)
        return p_next, v_next

    def train_step(state: DistSwarmState, batch: PyTree, eval_batch: PyTree,
                   key: Array) -> tuple[DistSwarmState, RoundInfo]:
        # per-worker coefficient draws (see core/mdsl.py)
        ckey, bkey, qkey, wkey = jax.random.split(key, 4)
        coeffs = jax.vmap(pso.sample_coefficients)(jax.random.split(ckey, W))
        lr = pso.decayed_lr(cfg.hp, state.round_idx)

        run_local = functools.partial(local_round, lr=lr)
        eval_one = lambda p: loss_fn(p, eval_batch)
        sq = lambda t: jax.tree.map(lambda x: x[0], t)
        ex = lambda t: jax.tree.map(lambda x: x[None], t)
        if W == 1:
            p1, v1 = run_local(sq(state.params), sq(state.velocity),
                               sq(state.best_params), state.gbest_params,
                               jax.tree.map(lambda x: x[0], batch),
                               coeffs=sq(coeffs))
            new_params, new_vel = ex(p1), ex(v1)
        else:
            vmapped = jax.vmap(run_local,
                               in_axes=(0, 0, 0, None, 0, 0),
                               spmd_axis_name=_spmd_axis_name(cfg))
            new_params, new_vel = vmapped(state.params, state.velocity,
                                          state.best_params,
                                          state.gbest_params, batch, coeffs)

        # Byzantine workers' local updates are adversarial (comm/channel):
        # corruption lands in their params so Eq. 6 can reject them.
        new_params = comm_channel.corrupt_local_updates(
            cfg.comm, state.params, new_params, bkey)
        if W == 1:
            losses = eval_one(sq(new_params))[None]
        else:
            losses = jax.vmap(eval_one)(new_params)

        # --- Eqs. 5-6: scores + adaptive-threshold selection -------------
        theta = selection.tradeoff_scores(losses, state.eta, cfg.tau)
        mask = (theta <= state.prev_theta_mean).astype(jnp.float32)
        best = jax.nn.one_hot(jnp.argmin(theta), W, dtype=jnp.float32)
        mask = jnp.where(mask.sum() > 0, mask, best)

        # --- Eq. 7 through the wire: compress (+ error feedback), push
        # through the channel, aggregate -> one all-reduce over worker
        # axes. Default CommConfig reduces to the seed's masked mean. ---
        delta = jax.tree.map(lambda a, b: a - b, new_params, state.params)
        if W == 1:
            w1, r1 = comm_compress.compress_with_ef(
                cfg.comm, sq(delta), sq(state.residual), qkey)
            wire, new_res = ex(w1), ex(r1)
        else:
            wire, new_res = jax.vmap(
                functools.partial(comm_compress.compress_with_ef, cfg.comm),
                spmd_axis_name=_spmd_axis_name(cfg)
            )(delta, state.residual, jax.random.split(qkey, W))
        residual = comm_compress.select_residual(mask, new_res,
                                                 state.residual)
        global_params, mask_eff = comm_channel.receive(
            cfg.comm, state.global_params, wire, mask, wkey)
        rec = comm_budget.round_record(cfg.comm, state.global_params, W,
                                       mask, mask_eff)
        global_loss = eval_one(global_params)

        # --- Eqs. 9-10: bests ---------------------------------------------
        improved = losses < state.best_loss
        sel_tree = lambda c, n, o: jax.tree.map(
            lambda a, b: jnp.where(
                c.reshape((-1,) + (1,) * (a.ndim - 1)), a, b), n, o)
        best_params = sel_tree(improved, new_params, state.best_params)
        best_loss = jnp.where(improved, losses, state.best_loss)
        g_improved = global_loss < state.gbest_loss
        gbest_params = jax.tree.map(
            lambda n, o: jnp.where(g_improved, n, o), global_params,
            state.gbest_params)

        next_state = DistSwarmState(
            params=new_params, velocity=new_vel, best_params=best_params,
            best_loss=best_loss, global_params=global_params,
            gbest_params=gbest_params,
            gbest_loss=jnp.minimum(global_loss, state.gbest_loss),
            prev_theta_mean=theta.mean(), eta=state.eta,
            round_idx=state.round_idx + 1, residual=residual)
        return next_state, RoundInfo(losses=losses, theta=theta, mask=mask,
                                     global_loss=global_loss,
                                     bytes_up=rec.bytes_up,
                                     delivered=rec.delivered)

    return train_step


def fedavg_train_step(loss_fn, cfg: DistSwarmConfig):
    """Baseline: plain data-parallel FedAvg round (all workers, SGD only).
    Used for paper-faithful comparisons at mesh scale and as the roofline
    reference for the selection overhead."""
    grad_fn = jax.value_and_grad(loss_fn)
    W = cfg.num_spatial

    def local(params, batch, lr):
        def sgd(p, _):
            if cfg.microbatches > 1:
                k = cfg.microbatches
                mbs = jax.tree.map(
                    lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]),
                    batch)

                def acc(g_sum, mb):
                    _, g = grad_fn(p, mb)
                    return jax.tree.map(
                        lambda s, gg: s + gg.astype(jnp.float32),
                        g_sum, g), None

                zeros = jax.tree.map(
                    lambda x: jnp.zeros(x.shape, jnp.float32), p)
                g, _ = jax.lax.scan(acc, zeros, mbs)
                g = jax.tree.map(lambda gg, pp: (gg / k).astype(pp.dtype),
                                 g, p)
            else:
                _, g = grad_fn(p, batch)
            return pso.sgd_step(p, g, lr), None
        trained, _ = jax.lax.scan(sgd, params, None, length=cfg.local_steps)
        return jax.tree.map(lambda a, b: a - b, trained, params)

    def train_step(state: DistSwarmState, batch, eval_batch, key):
        bkey, qkey, wkey = jax.random.split(key, 3)
        lr = pso.decayed_lr(cfg.hp, state.round_idx)
        if W == 1:
            delta = local(state.global_params,
                          jax.tree.map(lambda x: x[0], batch), lr)
            deltas = jax.tree.map(lambda x: x[None], delta)
        else:
            deltas = jax.vmap(
                lambda b: local(state.global_params, b, lr),
                spmd_axis_name=_spmd_axis_name(cfg))(batch)
        # FedAvg rides the same wire: byzantine deltas, compression with
        # error feedback, channel — but every worker uploads (mask = 1).
        zeros = jax.tree.map(jnp.zeros_like, deltas)
        deltas = comm_channel.corrupt_local_updates(cfg.comm, zeros,
                                                    deltas, bkey)
        mask = jnp.ones((W,), jnp.float32)
        if W == 1:
            sq = lambda t: jax.tree.map(lambda x: x[0], t)
            w1, r1 = comm_compress.compress_with_ef(
                cfg.comm, sq(deltas), sq(state.residual), qkey)
            wire = jax.tree.map(lambda x: x[None], w1)
            new_res = jax.tree.map(lambda x: x[None], r1)
        else:
            wire, new_res = jax.vmap(
                functools.partial(comm_compress.compress_with_ef, cfg.comm),
                spmd_axis_name=_spmd_axis_name(cfg)
            )(deltas, state.residual, jax.random.split(qkey, W))
        global_params, mask_eff = comm_channel.receive(
            cfg.comm, state.global_params, wire, mask, wkey)
        rec = comm_budget.round_record(cfg.comm, state.global_params, W,
                                       mask, mask_eff)
        global_loss = loss_fn(global_params, eval_batch)
        next_state = state._replace(global_params=global_params,
                                    round_idx=state.round_idx + 1,
                                    residual=new_res)
        info = RoundInfo(losses=jnp.zeros((W,)), theta=jnp.zeros((W,)),
                         mask=mask, global_loss=global_loss,
                         bytes_up=rec.bytes_up, delivered=rec.delivered)
        return next_state, info

    return train_step
