"""M-DSL as a mesh-distributed train step (production integration).

The paper's C edge workers map onto the mesh as data-parallel groups
(DESIGN.md §3): the swarm state carries a leading *spatial worker* dim W
sharded over `worker_axes`; each worker's replica is sharded over the
remaining axes (TP over "model", FSDP over "data" in fsdp mode). One
jitted `train_step` is one communication round, built as a thin
configuration of `core/rounds.py`'s stage pipeline: this module supplies
only the LocalUpdate stage (local SGD steps + Eq. 8 PSO displacement,
with `spmd_axis_name` vmap over W); ScoreSelect, the Eq.-7 wire
(compression, channel, robust aggregation, compressed downlink), and
byte accounting are the shared stages — the masked delta-mean lowers to
ONE all-reduce over worker_axes exactly as before.

vmap over the worker dim uses `spmd_axis_name=worker_axes` so internal
sharding constraints stay consistent with the worker sharding. With
W == 1 (fsdp mode: the time-multiplexed swarm) the local-update vmap is
skipped and `temporal_workers` rounds can be scanned by the caller.

`fedavg_train_step` is the same pipeline with the all-ones selection
stage (algorithm="fedavg") and plain-SGD local deltas — the baseline
rides the identical wire, so robust aggregation and downlink
compression apply to it too.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.comm import compress as comm_compress
from repro.comm import channel as comm_channel
from repro.comm import phy as comm_phy
from repro.comm import straggler as comm_straggler
from repro.comm.budget import CommConfig
from repro.core import pso, rounds
from repro.core.pso import PsoHyperParams
from repro.core.rounds import RoundTelemetry

Array = jax.Array
PyTree = Any

# pre-refactor alias: the mesh path's info is the unified telemetry
RoundInfo = RoundTelemetry


class DistSwarmConfig(NamedTuple):
    worker_axes: tuple[str, ...]    # () => single spatial worker (fsdp mode)
    num_spatial: int                # W
    local_steps: int = 1
    tau: float = 0.9
    hp: PsoHyperParams = PsoHyperParams(learning_rate=3e-3,
                                        velocity_clip=1.0)
    # grad-accumulation chunks per local step: caps per-device activation
    # memory at batch/microbatches (EXPERIMENTS.md §Perf iteration 2)
    microbatches: int = 1
    comm: CommConfig = CommConfig()  # wire: compression/channel/aggregation


class DistSwarmState(NamedTuple):
    """All worker leaves stacked over W; global leaves unstacked."""
    params: PyTree            # (W, ...) worker models
    velocity: PyTree          # (W, ...)
    best_params: PyTree       # (W, ...) w^l (Eq. 9)
    best_loss: Array          # (W,)
    global_params: PyTree     # w_t (replicated over worker axes)
    gbest_params: PyTree      # w^g-bar (Eq. 10)
    gbest_loss: Array         # ()
    prev_theta_mean: Array    # () Eq. 6 threshold
    eta: Array                # (W,) non-iid degrees
    round_idx: Array          # ()
    residual: PyTree          # (W, ...) uplink error-feedback state
    ps_residual: PyTree       # PS-side downlink error-feedback state
    phy: comm_phy.PhyState    # (W,) per-worker channel state (comm.phy)
    # (W, ...) parked late deltas + staleness ages (comm.straggler);
    # None unless comm.round_deadline_s is set
    buffer: Any = None


def init_state(global_params: PyTree, cfg: DistSwarmConfig,
               eta: Optional[Array] = None) -> DistSwarmState:
    W = cfg.num_spatial
    stack = lambda t: jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (W,) + x.shape), t)
    zeros = jax.tree.map(jnp.zeros_like, global_params)
    return DistSwarmState(
        params=stack(global_params),
        velocity=stack(zeros),
        best_params=stack(global_params),
        best_loss=jnp.full((W,), jnp.inf, jnp.float32),
        global_params=global_params,
        gbest_params=global_params,
        gbest_loss=jnp.asarray(jnp.inf, jnp.float32),
        prev_theta_mean=jnp.asarray(jnp.inf, jnp.float32),
        eta=jnp.zeros((W,), jnp.float32) if eta is None else eta,
        round_idx=jnp.zeros((), jnp.int32),
        residual=stack(comm_compress.init_residual(global_params)),
        ps_residual=rounds.init_ps_residual(global_params),
        phy=comm_phy.init_state(cfg.comm, W),
        buffer=comm_straggler.init_buffer(
            cfg.comm, stack(comm_compress.init_residual(global_params))),
    )


def _spmd_axis_name(cfg: DistSwarmConfig):
    """vmap spmd_axis_name for the worker dim: None when the worker dim is
    not mesh-sharded (pure-CPU tests / temporal-only swarm with W>1)."""
    if len(cfg.worker_axes) == 0:
        return None
    if len(cfg.worker_axes) == 1:
        return cfg.worker_axes[0]
    return cfg.worker_axes


def _pipeline(cfg: DistSwarmConfig, algorithm: str,
              params_template: PyTree = None) -> rounds.RoundPipeline:
    return rounds.RoundPipeline(
        algorithm=algorithm, comm=cfg.comm, num_workers=cfg.num_spatial,
        tau=cfg.tau, axis_name=_spmd_axis_name(cfg),
        n_params=(rounds.count_params(params_template)
                  if params_template is not None else 0))


def build_train_step(loss_fn: Callable[[PyTree, dict], Array],
                     cfg: DistSwarmConfig
                     ) -> Callable[..., tuple[DistSwarmState, RoundInfo]]:
    """loss_fn(params, batch) -> scalar. Returns
    train_step(state, batch, eval_batch, key) where every leaf of `batch`
    has a leading worker dim W."""

    W = cfg.num_spatial
    grad_fn = jax.value_and_grad(loss_fn)

    def local_round(params, velocity, best_params, gbest_params, batch,
                    coeffs=None, lr=None):
        """LocalUpdate: local SGD steps + Eq. 8 PSO displacement."""
        w0 = params

        def sgd(p, _):
            g = rounds.accumulated_grad(grad_fn, p, batch, cfg.microbatches)
            return pso.sgd_step(p, g, lr), None

        trained, _ = jax.lax.scan(sgd, w0, None, length=cfg.local_steps)
        sgd_delta = jax.tree.map(lambda a, b: a - b, trained, w0)

        def leaf(w, v, wl, wg, d):
            v_new = (coeffs.c0 * v + coeffs.c1 * (wl - w)
                     + coeffs.c2 * (wg - w) + d)
            if cfg.hp.velocity_clip > 0:
                v_new = jnp.clip(v_new, -cfg.hp.velocity_clip,
                                 cfg.hp.velocity_clip)
            return v_new.astype(w.dtype)
        v_next = jax.tree.map(leaf, w0, velocity, best_params, gbest_params,
                              sgd_delta)
        p_next = jax.tree.map(jnp.add, w0, v_next)
        return p_next, v_next

    def train_step(state: DistSwarmState, batch: PyTree, eval_batch: PyTree,
                   key: Array) -> tuple[DistSwarmState, RoundInfo]:
        pipe = _pipeline(cfg, "mdsl", state.global_params)
        # per-worker coefficient draws (see core/mdsl.py)
        ckey, bkey, qkey, wkey = jax.random.split(key, 4)
        coeffs = jax.vmap(pso.sample_coefficients)(jax.random.split(ckey, W))
        lr = pso.decayed_lr(cfg.hp, state.round_idx)

        run_local = functools.partial(local_round, lr=lr)
        eval_one = lambda p: loss_fn(p, eval_batch)
        sq = lambda t: jax.tree.map(lambda x: x[0], t)
        ex = lambda t: jax.tree.map(lambda x: x[None], t)
        with rounds.stage_span("LocalUpdate"):
            if W == 1:
                p1, v1 = run_local(sq(state.params), sq(state.velocity),
                                   sq(state.best_params),
                                   state.gbest_params,
                                   jax.tree.map(lambda x: x[0], batch),
                                   coeffs=sq(coeffs))
                new_params, new_vel = ex(p1), ex(v1)
            else:
                vmapped = jax.vmap(run_local,
                                   in_axes=(0, 0, 0, None, 0, 0),
                                   spmd_axis_name=_spmd_axis_name(cfg))
                new_params, new_vel = vmapped(state.params, state.velocity,
                                              state.best_params,
                                              state.gbest_params, batch,
                                              coeffs)

            # Byzantine workers' local updates are adversarial
            # (comm/channel): corruption lands in their params so Eq. 6
            # can reject them.
            new_params = comm_channel.corrupt_local_updates(
                cfg.comm, state.params, new_params, bkey)
            if W == 1:
                losses = eval_one(sq(new_params))[None]
            else:
                losses = jax.vmap(eval_one)(new_params)

        # --- ScoreSelect (Eqs. 5-6) ---------------------------------------
        theta, mask, theta_mean = pipe.select(losses, state.eta,
                                              state.prev_theta_mean)

        # --- Uplink -> Aggregate -> Downlink (Eq. 7 through the wire):
        # one all-reduce over worker axes; default CommConfig reduces to
        # the seed's masked mean and a dense broadcast. ---
        delta = jax.tree.map(lambda a, b: a - b, new_params, state.params)
        out = pipe.wire(delta=delta, theta=theta, mask=mask,
                        global_params=state.global_params,
                        residual=state.residual,
                        ps_residual=state.ps_residual,
                        qkey=qkey, wkey=wkey, phy=state.phy,
                        buffer=state.buffer, round_idx=state.round_idx)
        global_loss = eval_one(out.global_params)

        # --- BestTracking (Eqs. 9-10) -------------------------------------
        with rounds.stage_span("BestTracking"):
            best_params, best_loss = rounds.track_local_best(
                state.best_params, state.best_loss, new_params, losses)
            gbest_params, gbest_loss = rounds.track_global_best(
                state.gbest_params, state.gbest_loss, out.global_params,
                global_loss)

        next_state = DistSwarmState(
            params=new_params, velocity=new_vel, best_params=best_params,
            best_loss=best_loss, global_params=out.global_params,
            gbest_params=gbest_params, gbest_loss=gbest_loss,
            prev_theta_mean=theta_mean, eta=state.eta,
            round_idx=state.round_idx + 1, residual=out.residual,
            ps_residual=out.ps_residual, phy=out.phy, buffer=out.buffer)
        return next_state, pipe.telemetry(losses=losses, theta=theta,
                                          mask=mask,
                                          global_loss=global_loss,
                                          outcome=out)

    return train_step


def fedavg_train_step(loss_fn, cfg: DistSwarmConfig):
    """Baseline: plain data-parallel FedAvg round (all workers, SGD only)
    — the same pipeline with the all-ones selection stage. Used for
    paper-faithful comparisons at mesh scale and as the roofline
    reference for the selection overhead."""
    grad_fn = jax.value_and_grad(loss_fn)
    W = cfg.num_spatial

    def local(params, batch, lr):
        def sgd(p, _):
            g = rounds.accumulated_grad(grad_fn, p, batch, cfg.microbatches)
            return pso.sgd_step(p, g, lr), None
        trained, _ = jax.lax.scan(sgd, params, None, length=cfg.local_steps)
        return jax.tree.map(lambda a, b: a - b, trained, params)

    def train_step(state: DistSwarmState, batch, eval_batch, key):
        pipe = _pipeline(cfg, "fedavg", state.global_params)
        bkey, qkey, wkey = jax.random.split(key, 3)
        lr = pso.decayed_lr(cfg.hp, state.round_idx)
        with rounds.stage_span("LocalUpdate"):
            if W == 1:
                delta = local(state.global_params,
                              jax.tree.map(lambda x: x[0], batch), lr)
                deltas = jax.tree.map(lambda x: x[None], delta)
            else:
                deltas = jax.vmap(
                    lambda b: local(state.global_params, b, lr),
                    spmd_axis_name=_spmd_axis_name(cfg))(batch)
            # FedAvg rides the same wire: byzantine deltas, compression
            # with error feedback, channel — but every worker uploads
            # (mask = 1).
            zeros = jax.tree.map(jnp.zeros_like, deltas)
            deltas = comm_channel.corrupt_local_updates(cfg.comm, zeros,
                                                        deltas, bkey)
            # real per-worker scores: F_i at w_t + delta_i on the eval
            # batch
            worker_params = jax.tree.map(lambda g, d: g[None] + d,
                                         state.global_params, deltas)
            eval_one = lambda p: loss_fn(p, eval_batch)
            if W == 1:
                losses = eval_one(jax.tree.map(lambda x: x[0],
                                               worker_params))[None]
            else:
                losses = jax.vmap(eval_one)(worker_params)
        theta, mask, _ = pipe.select(losses, state.eta,
                                     state.prev_theta_mean)

        out = pipe.wire(delta=deltas, theta=theta, mask=mask,
                        global_params=state.global_params,
                        residual=state.residual,
                        ps_residual=state.ps_residual,
                        qkey=qkey, wkey=wkey, phy=state.phy,
                        buffer=state.buffer, round_idx=state.round_idx)
        global_loss = loss_fn(out.global_params, eval_batch)
        next_state = state._replace(global_params=out.global_params,
                                    round_idx=state.round_idx + 1,
                                    residual=out.residual,
                                    ps_residual=out.ps_residual,
                                    phy=out.phy, buffer=out.buffer)
        return next_state, pipe.telemetry(losses=losses, theta=theta,
                                          mask=mask,
                                          global_loss=global_loss,
                                          outcome=out)

    return train_step
