from repro.data.synthetic import (SyntheticImageSpec, MNIST_LIKE, CIFAR_LIKE,
                                  make_class_prototypes, sample_dataset,
                                  sample_labels_dirichlet)
from repro.data.partition import (dirichlet_partition, mixed_dirichlet_partition,
                                  iid_partition)
