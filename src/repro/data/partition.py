"""Worker dataset partitioning (paper §V-A).

Builds the C stacked local datasets {D_i} (each |D_i|=512 by default) plus
the shared synthetic evaluation set D_g (|D_g|=2048), under three regimes
from §V-B:

  iid          : every worker draws labels uniformly
  non-iid I    : every worker's label proportions ~ Dirichlet(alpha=0.5)
  non-iid II   : mixed fleet — 20 workers at alpha=0.1, 15 at 0.5,
                 10 at 1.0, 5 at 10.0 (Fig. 2)
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.data import synthetic
from repro.data.synthetic import SyntheticImageSpec

Array = jax.Array


class FederatedData(NamedTuple):
    x: Array           # (C, n_i, H, W, ch)
    y: Array           # (C, n_i) int32
    global_x: Array    # (n_g, H, W, ch)  — D_g
    global_y: Array    # (n_g,)
    test_x: Array      # held-out i.i.d. test set
    test_y: Array
    alphas: Array      # (C,) generation parameter per worker (for analysis)


def _build(key: Array, per_worker_labels: Array, spec: SyntheticImageSpec,
           n_global: int, n_test: int, alphas: Array) -> FederatedData:
    C, n_i = per_worker_labels.shape
    k_proto, k_local, k_g, k_gy, k_t, k_ty = jax.random.split(key, 6)
    prototypes = synthetic.make_class_prototypes(k_proto, spec)

    local_x = jax.vmap(
        lambda k, lab: synthetic.sample_images(k, lab, prototypes, spec)
    )(jax.random.split(k_local, C), per_worker_labels)

    gy = synthetic.uniform_labels(k_gy, n_global, spec.num_classes)
    gx = synthetic.sample_images(k_g, gy, prototypes, spec)
    ty = synthetic.uniform_labels(k_ty, n_test, spec.num_classes)
    tx = synthetic.sample_images(k_t, ty, prototypes, spec)
    return FederatedData(x=local_x, y=per_worker_labels, global_x=gx,
                         global_y=gy, test_x=tx, test_y=ty, alphas=alphas)


def iid_partition(key: Array, num_workers: int, spec: SyntheticImageSpec,
                  n_local: int = 512, n_global: int = 2048,
                  n_test: int = 2048) -> FederatedData:
    k_lab, k_rest = jax.random.split(key)
    labels = jax.vmap(
        lambda k: synthetic.uniform_labels(k, n_local, spec.num_classes)
    )(jax.random.split(k_lab, num_workers))
    alphas = jnp.full((num_workers,), jnp.inf)
    return _build(k_rest, labels, spec, n_global, n_test, alphas)


def dirichlet_partition(key: Array, num_workers: int, alpha: float,
                        spec: SyntheticImageSpec, n_local: int = 512,
                        n_global: int = 2048,
                        n_test: int = 2048) -> FederatedData:
    """Non-i.i.d. case I: uniform alpha across the fleet."""
    return mixed_dirichlet_partition(key, [(num_workers, alpha)], spec,
                                     n_local, n_global, n_test)


def mixed_dirichlet_partition(key: Array,
                              groups: Sequence[tuple[int, float]],
                              spec: SyntheticImageSpec, n_local: int = 512,
                              n_global: int = 2048,
                              n_test: int = 2048) -> FederatedData:
    """Non-i.i.d. case II (Fig. 2): `groups` is [(count, alpha), ...]."""
    k_lab, k_rest = jax.random.split(key)
    alphas = jnp.concatenate(
        [jnp.full((cnt,), a) for cnt, a in groups])
    C = int(alphas.shape[0])
    keys = jax.random.split(k_lab, C)
    labels = jnp.stack([
        synthetic.sample_labels_dirichlet(keys[i], float(alphas[i]), n_local,
                                          spec.num_classes)
        for i in range(C)])
    return _build(k_rest, labels, spec, n_global, n_test, alphas)
