"""Synthetic class-conditional image data (offline stand-in for MNIST/CIFAR10).

MNIST/CIFAR10 are unavailable in this offline container (DESIGN.md §1), so
we generate datasets with the same interface and cardinalities: each class
c has a fixed random spatial prototype; a sample is prototype + structured
noise + per-sample random contrast/shift. The task is learnable (a linear
probe reaches high accuracy given enough i.i.d. data) yet noisy enough
that distributed non-i.i.d. training exhibits the degradation the paper
studies. The synthetic global dataset D_g (GAN-generated in the paper) is
drawn i.i.d. from the same generator with uniform labels.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class SyntheticImageSpec(NamedTuple):
    name: str
    height: int
    width: int
    channels: int
    num_classes: int
    noise_scale: float = 0.8
    prototype_scale: float = 1.0


MNIST_LIKE = SyntheticImageSpec("mnist_like", 28, 28, 1, 10, noise_scale=0.6)
CIFAR_LIKE = SyntheticImageSpec("cifar_like", 32, 32, 3, 10, noise_scale=1.0)


def make_class_prototypes(key: Array, spec: SyntheticImageSpec) -> Array:
    """(num_classes, H, W, C) fixed random prototypes, low-pass filtered so
    classes differ in coarse structure (like real image classes)."""
    raw = jax.random.normal(
        key, (spec.num_classes, spec.height, spec.width, spec.channels))
    # cheap 3x3 box blur, twice, to create spatial correlation
    def blur(x):
        pad = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)), mode="edge")
        acc = sum(pad[:, i:i + spec.height, j:j + spec.width, :]
                  for i in range(3) for j in range(3))
        return acc / 9.0
    smooth = blur(blur(raw))
    # re-standardize per class: the blur shrinks variance ~9x per pass,
    # which would bury the class signal under the sample noise
    mean = smooth.mean(axis=(1, 2, 3), keepdims=True)
    std = smooth.std(axis=(1, 2, 3), keepdims=True)
    return spec.prototype_scale * (smooth - mean) / (std + 1e-6)


def sample_images(key: Array, labels: Array, prototypes: Array,
                  spec: SyntheticImageSpec) -> Array:
    """Draw images for given int labels: prototype[label] * contrast + noise."""
    n = labels.shape[0]
    k_noise, k_con = jax.random.split(key)
    base = prototypes[labels]
    contrast = 1.0 + 0.3 * jax.random.normal(k_con, (n, 1, 1, 1))
    noise = spec.noise_scale * jax.random.normal(k_noise, base.shape)
    return base * contrast + noise


def sample_labels_dirichlet(key: Array, alpha: float, n: int,
                            num_classes: int) -> Array:
    """Labels for one worker: class proportions ~ Dir(alpha), then n draws.

    This is the paper's generation scheme [6]: small alpha => the worker
    sees only a few classes (high label skew); large alpha => near-uniform.
    """
    k_prop, k_draw = jax.random.split(key)
    props = jax.random.dirichlet(k_prop, alpha * jnp.ones(num_classes))
    return jax.random.categorical(
        k_draw, jnp.log(props + 1e-12)[None, :].repeat(n, axis=0))


def sample_dataset(key: Array, labels: Array, prototypes: Array,
                   spec: SyntheticImageSpec) -> tuple[Array, Array]:
    """(x, y) for given labels."""
    return sample_images(key, labels, prototypes, spec), labels


def uniform_labels(key: Array, n: int, num_classes: int) -> Array:
    return jax.random.randint(key, (n,), 0, num_classes)
