"""repro.experiments — declarative experiment specs, scenario registry,
and the single `run()` front door.

The paper's §V evaluation grid (algorithm x partition case x dataset x
comm channel), plus the related work's Byzantine and channel-aware
axes, as typed data:

  spec.py      frozen `ExperimentSpec` dataclass tree with validate(),
               JSON round-trip (to_dict/from_dict), and dotted-path
               override("comm.compressor=topk") for sweeps
  registry.py  named presets (paper/fig3-*, byzantine-*, low-bandwidth,
               lossy/noisy uplink, adaptive tiers, mesh smokes) behind
               list_scenarios()/get_scenario()
  runner.py    build(spec)/run(spec)->RunResult/sweep(specs) subsuming
               the legacy launch/train.py drivers (kept as shims)

Typical use:

    from repro.experiments import get_scenario, override, run
    result = run(override(get_scenario("paper/fig3-noniid1"),
                          "run.rounds=2", "comm.compressor=int8"))
"""
from repro.experiments.registry import (describe_scenarios, get_scenario,
                                        list_scenarios, register_scenario)
from repro.experiments.runner import (SCHEMA_VERSION, Prepared, RunResult,
                                      build, default_out, load_result, run,
                                      sweep)
from repro.experiments.spec import (AlgoSpec, DataSpec, ExperimentSpec,
                                    ModelSpec, ObsConfig, RunSpec,
                                    from_dict, override, to_dict)

__all__ = ["AlgoSpec", "DataSpec", "ExperimentSpec", "ModelSpec",
           "ObsConfig", "Prepared", "RunResult", "RunSpec",
           "SCHEMA_VERSION", "build", "default_out", "describe_scenarios",
           "from_dict", "get_scenario", "list_scenarios", "load_result",
           "override", "register_scenario", "run", "sweep", "to_dict"]
