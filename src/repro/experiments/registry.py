"""Named scenario presets — the single front door to the experiment grid.

Every paper figure, comm regime, and mesh smoke run is one named,
validated `ExperimentSpec` here. Entry points (`launch/train.py
--scenario`, the benchmarks, the examples) look scenarios up instead of
re-assembling MdslConfig/CommConfig/partition plumbing by hand; sweeps
start from a preset and `override()` the axis they vary.

    >>> from repro.experiments import get_scenario, override, run
    >>> spec = override(get_scenario("paper/fig3-noniid1"), "run.rounds=2")
    >>> result = run(spec)

Conventions: `paper/…` names reproduce a figure or table of the source
paper; bare names are comm/robustness regimes from the related work
(CB-DSL arXiv:2208.05578, analog M-DSL arXiv:2510.18152); `mesh/…`
names drive the production mesh path on a reduced assigned arch.
"""
from __future__ import annotations

import dataclasses

from repro.comm.budget import CommConfig
from repro.core.pso import PsoHyperParams
from repro.experiments.spec import (AlgoSpec, DataSpec, ExperimentSpec,
                                    ModelSpec, PopulationSpec, RunSpec)

_SCENARIOS: dict[str, ExperimentSpec] = {}


def register_scenario(spec: ExperimentSpec) -> ExperimentSpec:
    """Add a named preset (validated; name collisions are an error)."""
    if not spec.name:
        raise ValueError("scenario specs must carry a name")
    if spec.name in _SCENARIOS:
        raise ValueError(f"scenario {spec.name!r} already registered")
    _SCENARIOS[spec.name] = spec.validate()
    return spec


def list_scenarios() -> list[str]:
    """All registered scenario names, sorted."""
    return sorted(_SCENARIOS)


def get_scenario(name: str) -> ExperimentSpec:
    """Look up a preset by name (specs are frozen — safe to share)."""
    try:
        return _SCENARIOS[name]
    except KeyError:
        raise ValueError(f"unknown scenario {name!r}; available: "
                         f"{', '.join(list_scenarios())}") from None


def describe_scenarios() -> list[tuple[str, str]]:
    """(name, one-line summary) rows for CLI/README tables."""
    rows = []
    for name in list_scenarios():
        s = _SCENARIOS[name]
        if s.model.kind == "paper":
            what = (f"{s.algo.algorithm}/{s.data.case}/{s.data.dataset} "
                    f"C={s.data.num_workers} R={s.run.rounds}")
        else:
            what = (f"{s.model.name} W={s.data.num_workers} "
                    f"steps={s.run.rounds}")
        if s.fleet.population:
            what = (f"{s.algo.algorithm}/{s.data.case}/{s.data.dataset} "
                    f"P={s.fleet.population} K={s.data.num_workers}"
                    f"/{s.fleet.cohort_policy} R={s.run.rounds}")
        wire = []
        if s.comm.compressor != "identity":
            wire.append(s.comm.compressor)
        if s.comm.downlink_compressor != "identity":
            wire.append(f"down:{s.comm.downlink_compressor}")
        if s.comm.channel != "ideal":
            wire.append(s.comm.channel)
        if s.comm.fading != "none":
            wire.append(f"{s.comm.fading}@{s.comm.doppler_rho}")
        if s.comm.outage_snr_db is not None:
            wire.append(f"outage>{s.comm.outage_snr_db:g}dB")
        if s.comm.byzantine:
            wire.append(f"byz={s.comm.byzantine}:{s.comm.aggregator}")
        if s.comm.adaptive_bits:
            wire.append(f"tiers={s.comm.num_tiers}:{s.comm.tier_rank}")
        if s.comm.round_deadline_s is not None:
            wire.append(f"ddl={s.comm.round_deadline_s:g}s"
                        f"/γ={s.comm.staleness_gamma:g}")
            if s.comm.quorum:
                wire.append(f"quorum={s.comm.quorum}")
        if s.comm.fault_prob:
            wire.append(f"faults={s.comm.fault_prob:g}"
                        f"x{s.comm.fault_rounds}r")
        rows.append((name, what + (f" [{' '.join(wire)}]" if wire else "")))
    return rows


# ---------------------------------------------------------------------------
# Presets
# ---------------------------------------------------------------------------

# Paper §V-A settings: C=50, 5-layer CNN width x8, 4 local epochs,
# batch 64, lr 0.01 decayed, tau=0.9 — the Fig. 3 operating point.
_PAPER_HP = PsoHyperParams(learning_rate=0.01, velocity_clip=0.1)
_FIG3 = ExperimentSpec(
    data=DataSpec(dataset="mnist_like", num_workers=50, n_local=512),
    model=ModelSpec(kind="paper", name="cnn", width_mult=8),
    algo=AlgoSpec(algorithm="mdsl", tau=0.9, local_epochs=4, batch_size=64,
                  hp=_PAPER_HP),
    run=RunSpec(rounds=20),
)


def _fig3(name: str, **data_kw) -> ExperimentSpec:
    return dataclasses.replace(
        _FIG3, name=name, data=dataclasses.replace(_FIG3.data, **data_kw))


def _comm(name: str, comm: CommConfig) -> ExperimentSpec:
    return dataclasses.replace(_fig3(name, case="noniid1"), comm=comm)


# -- paper figures ----------------------------------------------------------
for _case in ("iid", "noniid1", "noniid2"):
    register_scenario(_fig3(f"paper/fig3-{_case}", case=_case))
register_scenario(_fig3("paper/fig3-cifar-noniid1", dataset="cifar_like",
                        case="noniid1"))

# -- robustness regimes (CB-DSL's Byzantine setting, arXiv:2208.05578) ------
register_scenario(dataclasses.replace(
    _comm("byzantine-median",
          CommConfig(byzantine=3, byzantine_mode="gaussian",
                     byzantine_scale=25.0, aggregator="median")),
    algo=dataclasses.replace(_FIG3.algo, algorithm="fedavg")))
register_scenario(dataclasses.replace(
    _comm("byzantine-trimmed",
          CommConfig(byzantine=3, byzantine_mode="gaussian",
                     byzantine_scale=25.0, aggregator="trimmed_mean",
                     trim_ratio=0.2)),
    algo=dataclasses.replace(_FIG3.algo, algorithm="fedavg")))

# -- comm regimes (channel-aware M-DSL, arXiv:2510.18152) -------------------
register_scenario(_comm("low-bandwidth-int4",
                        CommConfig(compressor="int4",
                                   downlink_compressor="int8")))
register_scenario(_comm("low-bandwidth-topk",
                        CommConfig(compressor="topk", topk_ratio=0.05)))
register_scenario(_comm("lossy-uplink-erasure",
                        CommConfig(channel="erasure", drop_prob=0.3)))
register_scenario(_comm("noisy-uplink-awgn",
                        CommConfig(channel="awgn", snr_db=10.0)))
register_scenario(_comm("adaptive-tiers",
                        CommConfig(compressor="int8", adaptive_bits=True)))

# -- physical-layer regimes (comm.phy: Rayleigh uplinks, SNR->rate) ---------
register_scenario(_comm("rayleigh-uplink",
                        CommConfig(channel="awgn", snr_db=10.0,
                                   fading="rayleigh", doppler_rho=0.9)))
register_scenario(_comm("rayleigh-outage",
                        CommConfig(channel="composite", drop_prob=0.05,
                                   snr_db=10.0, fading="rayleigh",
                                   doppler_rho=0.9, outage_snr_db=0.0)))
register_scenario(_comm("snr-tiered-bits",
                        CommConfig(channel="awgn", snr_db=15.0,
                                   fading="rayleigh", doppler_rho=0.9,
                                   adaptive_bits=True, num_tiers=3,
                                   tier_rank="snr")))
register_scenario(_comm("energy-budget",
                        CommConfig(channel="awgn", snr_db=5.0,
                                   fading="rayleigh", doppler_rho=0.8,
                                   compressor="int4", tx_power_w=0.2,
                                   bandwidth_hz=200e3,
                                   pathloss_spread_db=6.0)))

# -- straggler / deadline regimes (comm.straggler: FedBuff-style async) -----
# Deadlines calibrated against the fig3 C=50 width-8 model: its dense
# payload is ~113 KiB, i.e. ~0.16 s of airtime at the 20 dB / 1 MHz link
# budget — so 0.2 s makes the faded/far tail late while near workers
# stay on time (benchmarks/comm_efficiency.py sweeps this axis).
register_scenario(_comm("straggler/deadline-tight",
                        CommConfig(fading="rayleigh", doppler_rho=0.9,
                                   pathloss_spread_db=6.0,
                                   round_deadline_s=0.2,
                                   staleness_gamma=0.5, quorum=10)))
register_scenario(_comm("straggler/fedbuff",
                        CommConfig(fading="rayleigh", doppler_rho=0.9,
                                   round_deadline_s=0.25,
                                   staleness_gamma=1.0)))

# -- small teaching fleets (the examples) -----------------------------------
register_scenario(ExperimentSpec(
    name="quickstart",
    data=DataSpec(dataset="mnist_like", case="noniid1", num_workers=8,
                  n_local=256),
    model=ModelSpec(kind="paper", name="cnn", width_mult=2),
    algo=AlgoSpec(algorithm="mdsl", tau=0.9, local_epochs=1, batch_size=64,
                  hp=PsoHyperParams(learning_rate=0.01, velocity_clip=1.0)),
    run=RunSpec(rounds=4),
))
register_scenario(ExperimentSpec(
    name="edge-iot/noniid2",
    data=DataSpec(dataset="mnist_like", case="noniid2", num_workers=10,
                  n_local=256),
    model=ModelSpec(kind="paper", name="cnn", width_mult=2),
    algo=AlgoSpec(algorithm="mdsl", tau=0.9, local_epochs=1, batch_size=64,
                  hp=_PAPER_HP),
    run=RunSpec(rounds=8),
))

# -- sampled-cohort fleets (core/population: P registered, K active) --------
_FLEET = ExperimentSpec(
    data=DataSpec(dataset="mnist_like", case="noniid1", num_workers=16,
                  n_local=128),
    model=ModelSpec(kind="paper", name="cnn", width_mult=2),
    algo=AlgoSpec(algorithm="mdsl", tau=0.9, local_epochs=1, batch_size=64,
                  hp=_PAPER_HP),
    run=RunSpec(rounds=10),
)
register_scenario(dataclasses.replace(
    _FLEET, name="fleet/million-uniform",
    fleet=PopulationSpec(population=1_000_000, cohort_size=16,
                         cohort_policy="uniform")))
register_scenario(dataclasses.replace(
    _FLEET, name="fleet/million-score",
    fleet=PopulationSpec(population=1_000_000, cohort_size=16,
                         cohort_policy="score_weighted"),
    # Rayleigh fading so the O(K) lazy catch-up (rho^Δ closed form) is
    # exercised: resampled devices re-enter with compressed idle rounds
    comm=CommConfig(channel="awgn", snr_db=10.0, fading="rayleigh",
                    doppler_rho=0.9)))

# -- fault injection: deterministic worker churn (comm.straggler) -----------
# The fleet-scale robustness run: every round each of the 16 workers
# starts a 2-round outage with p=0.15, the ~21 ms deadline makes faded
# workers late (the w=2 payload is ~7.5 KiB: ~11 ms of airtime at the
# 20 dB budget), and the quorum holds w_t when churn + fades thin the
# round below 4 deltas. tests/test_straggler.py pins recovery.
register_scenario(dataclasses.replace(
    _FLEET, name="faults/churn",
    comm=CommConfig(fading="rayleigh", doppler_rho=0.9,
                    pathloss_spread_db=3.0, round_deadline_s=0.02,
                    staleness_gamma=0.5, quorum=4,
                    fault_prob=0.15, fault_rounds=2)))

# -- mesh smoke runs (production path, reduced archs) -----------------------
_MESH_HP = PsoHyperParams(learning_rate=3e-3, velocity_clip=1.0)
for _arch in ("smollm-360m", "xlstm-350m"):
    register_scenario(ExperimentSpec(
        name=f"mesh/{_arch.split('-')[0]}-smoke",
        data=DataSpec(num_workers=2),
        model=ModelSpec(kind="mesh", name=_arch, reduced=True, seq_len=128,
                        per_worker_batch=2),
        algo=AlgoSpec(algorithm="mdsl", tau=0.9, local_steps=1, hp=_MESH_HP),
        run=RunSpec(rounds=5),
    ))
