"""`run(spec) -> RunResult`: one facade over both training drivers.

The paper driver (C-worker image fleet, `core/mdsl.py`) and the mesh
driver (reduced assigned arch on the active devices, `core/swarm_dist`)
used to live as two hand-wired functions in `launch/train.py` with ~18
positional kwargs each; this module is their single spec-driven home:

    build(spec)   -> Prepared   data/model/state + a uniform step fn
    run(spec)     -> RunResult  the full metrics record (legacy format)
    sweep(specs)  -> [RunResult] scenarios x seeds, artifacts embedding
                                 the full spec

The legacy entry points (`run_paper_experiment`, `run_mesh_training`)
survive as thin deprecated shims in `launch/train.py`, golden-pinned to
emit byte-identical metrics (modulo timing) on the default path.
"""
from __future__ import annotations

import contextlib
import functools
import json
import sys
import time
from pathlib import Path
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.budget import (dense_bytes, downlink_config,
                               host_round_bytes, payload_bytes)
from repro.data import partition
from repro.data.synthetic import CIFAR_LIKE, MNIST_LIKE
from repro.experiments.spec import ExperimentSpec, override, to_dict
from repro.obs import trace as obs_trace
from repro.obs.events import NULL, Emitter, new_run_id
from repro.obs.sinks import CsvSink, FanoutSink, JsonlSink, default_obs_dir

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts"

# Artifact format version. 1 = pre-obs {"spec", "metrics"}; 2 adds
# top-level "schema" and "events" (the run's JSONL stream path, null
# when obs was disabled). The metrics record itself is unchanged —
# golden pins compare it field-for-field across versions.
SCHEMA_VERSION = 2


def load_result(path: str | Path) -> dict:
    """Load a run artifact, failing loudly on unknown schema versions
    instead of letting downstream scripts KeyError on a shape they were
    never written for. Returns the raw dict with "schema" normalized
    (pre-version artifacts are schema 1)."""
    d = json.loads(Path(path).read_text())
    schema = d.get("schema", 1)
    if schema not in (1, 2):
        raise ValueError(
            f"{path}: artifact schema {schema!r} is newer than this "
            f"reader (knows 1..{SCHEMA_VERSION}) — upgrade the repo or "
            f"re-run the experiment")
    if not isinstance(d.get("metrics"), dict):
        raise ValueError(f"{path}: not a run artifact (no metrics dict)")
    d["schema"] = schema
    return d


def _noniid2_groups(C: int) -> list[tuple[int, float]]:
    """Fig. 2 fleet (20 @ 0.1, 15 @ 0.5, 10 @ 1.0, 5 @ 10.0), scaled
    proportionally to C workers (quick-mode benchmarks use C < 50)."""
    fracs = [(0.4, 0.1), (0.3, 0.5), (0.2, 1.0), (0.1, 10.0)]
    counts = [max(1, round(f * C)) for f, _ in fracs]
    counts[0] += C - sum(counts)  # absorb rounding into the largest group
    return [(c, a) for c, (_, a) in zip(counts, fracs)]


def _dirichlet(alpha: float):
    return lambda key, C, spec, n: partition.dirichlet_partition(
        key, C, alpha, spec, n_local=n)


# mutable on purpose: legacy callers (benchmarks/fig1_metric.py) used to
# monkeypatch entries; new code sets DataSpec.alpha instead
CASES = {
    "iid": lambda key, C, spec, n: partition.iid_partition(
        key, C, spec, n_local=n),
    "noniid1": _dirichlet(0.5),
    "noniid2": lambda key, C, spec, n: partition.mixed_dirichlet_partition(
        key, _noniid2_groups(C), spec, n_local=n),
}
IMAGE_SPECS = {"mnist_like": MNIST_LIKE, "cifar_like": CIFAR_LIKE}


def make_case_data(case: str, dataset: str, num_workers: int, seed: int,
                   n_local: int = 512, alpha: Optional[float] = None):
    """Partitioned fleet data for one case. `alpha` overrides the
    Dirichlet concentration of the noniid1 case (DataSpec.alpha)."""
    spec = IMAGE_SPECS[dataset]
    case_fn = (_dirichlet(alpha) if case == "noniid1" and alpha is not None
               else CASES[case])
    return case_fn(jax.random.PRNGKey(seed), num_workers, spec, n_local), spec


class Prepared(NamedTuple):
    """A built (but not yet run) experiment: everything `run` loops over.

    `step(state, key) -> (state, telemetry, key)` advances one
    communication round, consuming randomness exactly as the legacy
    drivers did (so default-path runs stay golden-pinned)."""
    spec: ExperimentSpec
    state: Any
    step: Callable[[Any, jax.Array], tuple[Any, Any, jax.Array]]
    key: jax.Array
    n_params: int
    aux: dict


class RunResult(NamedTuple):
    """A finished run: the spec that produced it + the metrics record
    (the record is the legacy metrics-JSON dict, unchanged) + the path
    of the run's obs event stream (None when obs was disabled)."""
    spec: ExperimentSpec
    record: dict
    events_path: Optional[str] = None

    def to_dict(self) -> dict:
        return {"schema": SCHEMA_VERSION, "spec": to_dict(self.spec),
                "metrics": self.record, "events": self.events_path}

    def save(self, path: str | Path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_dict(), indent=1))
        return path


# ---------------------------------------------------------------------------
# Paper driver (§V: C edge workers on partitioned synthetic image data)
# ---------------------------------------------------------------------------

def _prepare_paper(spec: ExperimentSpec) -> Prepared:
    from repro.configs.paper_cnn import paper_cnn, paper_resnet
    from repro.core import losses as losses_mod
    from repro.core import mdsl, noniid
    from repro.core.mdsl import MdslConfig

    d, a, r = spec.data, spec.algo, spec.run
    data, img_spec = make_case_data(d.case, d.dataset, d.num_workers,
                                    r.seed, d.n_local, alpha=d.alpha)
    img_model = (paper_cnn(img_spec, spec.model.width_mult)
                 if spec.model.name == "cnn"
                 else paper_resnet(img_spec, spec.model.width_mult))
    L = img_spec.num_classes

    loss_fn = lambda p, x, y: losses_mod.cross_entropy_loss(
        img_model.apply(p, x), y, L)
    eval_fn = lambda p, x, y: losses_mod.rmse_loss(  # Eq. 3 scoring on D_g
        img_model.apply(p, x), y, L)

    coeffs = (noniid.EtaCoefficients(*d.eta_coeffs) if d.eta_coeffs
              else (noniid.MNIST_COEFFS if d.dataset == "mnist_like"
                    else noniid.CIFAR10_COEFFS))
    eta = noniid.noniid_degree_from_labels(data.y, data.global_y, L, coeffs)

    cfg = MdslConfig(algorithm=a.algorithm, tau=a.tau,
                     local_epochs=a.local_epochs, batch_size=a.batch_size,
                     hp=a.hp, comm=spec.comm)
    key = jax.random.PRNGKey(r.seed + 1)
    state = mdsl.init_state(key, img_model.init, d.num_workers, eta,
                            comm=spec.comm)
    n_params = mdsl.count_params(state.global_params)

    @jax.jit
    def test_accuracy(params):
        return losses_mod.accuracy(img_model.apply(params, data.test_x),
                                   data.test_y)

    def step(state, key):
        key, rkey = jax.random.split(key)
        state, metrics = mdsl.mdsl_round(
            state, data.x, data.y, data.global_x, data.global_y, rkey,
            loss_fn=loss_fn, eval_fn=eval_fn, cfg=cfg, n_params=n_params)
        return state, metrics, key

    return Prepared(spec=spec, state=state, step=step, key=key,
                    n_params=n_params,
                    aux={"data": data, "model": img_model, "eta": eta,
                         "cfg": cfg, "test_accuracy": test_accuracy})


class _PopulationState(NamedTuple):
    """Engine state wrapped by the population scheduler: the K-cohort
    engine state, the O(P)-scalar device registry, the device ids
    holding the K slots, and the host round counter driving the lazy
    catch-up arithmetic."""
    inner: Any               # SwarmTrainState over the K cohort slots
    table: Any               # population.PopulationTable over P devices
    cohort: jax.Array        # (K,) int32 device ids seated in the slots
    t: int                   # next round index (host-side)

    @property
    def global_params(self):
        return self.inner.global_params


def _wrap_population(prep: Prepared) -> Prepared:
    """Lift a prepared K-worker paper run into a P-device fleet.

    Per round: fold POP_SALT off the round key (the inner engine's
    legacy key chain is never advanced), sample the K-cohort, gather
    its channel rows with lazy fading catch-up, reseat changed slots
    (fresh devices join at the current global model with zero velocity
    and reset personal bests — `pso.init_worker_state` semantics — and
    a zero uplink EF residual), run the inner round UNCHANGED, then
    scatter the cohort's post-round scalars back into the table. Model
    state stays O(K); the registry stays O(P) scalars.

    Degenerate anchor: population == cohort_size under the uniform
    policy samples the identity cohort, the reseat mask is all-False
    (every `jnp.where` returns its stored operand bitwise), and the
    gather's lag-0 guards pass the scattered channel rows back
    untouched — such runs are bit-identical to the unwrapped engine.

    Known limitation (documented in docs/population.md): worker data
    partitions and the eta non-iid degrees are SLOT-resident, not
    device-resident — device p seated in slot k trains on partition k.
    The fleet axis models channels, schedules, and staleness, not P
    distinct datasets."""
    from repro.core import population as pop
    from repro.core.pso import WorkerState

    spec = prep.spec
    f, comm = spec.fleet, spec.comm
    K = spec.data.num_workers
    inner_step = prep.step
    schedule = functools.partial(pop.schedule, comm=comm, cohort_size=K,
                                 policy=f.cohort_policy)

    @jax.jit
    def reseat(inner, changed, phy):
        def mix(fresh, old):
            return jax.tree.map(
                lambda fl, ol: jnp.where(
                    changed.reshape((-1,) + (1,) * (fl.ndim - 1)), fl, ol),
                fresh, old)
        g = inner.global_params
        bcast = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (K,) + x.shape), g)
        inf = jnp.full((K,), jnp.inf, jnp.float32)
        fresh_workers = WorkerState(
            params=bcast, velocity=jax.tree.map(jnp.zeros_like, bcast),
            best_params=bcast, best_loss=inf, prev_loss=inf)
        buf = inner.buffer
        if buf is not None:
            # a parked late delta belongs to the device that uploaded
            # it: clear reseated slots so a stranger's stale update
            # can't drain into the new occupant's rounds
            buf = buf._replace(
                delta=mix(jax.tree.map(jnp.zeros_like, buf.delta),
                          buf.delta),
                age=jnp.where(changed, 0, buf.age))
        return inner._replace(
            workers=mix(fresh_workers, inner.workers),
            residual=mix(jax.tree.map(jnp.zeros_like, inner.residual),
                         inner.residual),
            phy=phy, buffer=buf)

    @jax.jit
    def scatter(table, idx, inner, theta, round_idx):
        return pop.scatter_round(
            table, idx, inner.phy, theta,
            pop.residual_norms(inner.residual), round_idx)

    def step(state, key):
        t = jnp.int32(state.t)
        pkey = jax.random.fold_in(key, pop.POP_SALT)
        idx, phy = schedule(state.table, t, pkey)
        inner = reseat(state.inner, idx != state.cohort, phy)
        inner, metrics, key = inner_step(inner, key)
        table = scatter(state.table, idx, inner, metrics.theta, t)
        return (_PopulationState(inner=inner, table=table, cohort=idx,
                                 t=state.t + 1),
                metrics._replace(cohort=idx), key)

    table = pop.init_table(comm, f.population)
    state0 = _PopulationState(
        inner=prep.state, table=table,
        cohort=jnp.arange(K, dtype=jnp.int32), t=0)
    aux = dict(prep.aux, population=f.population,
               table_bytes=pop.table_bytes(table))
    return prep._replace(state=state0, step=step, aux=aux)


def _round_window(profiler, t: int):
    """The per-round profiler window (nullcontext when not profiling)."""
    return profiler.round(t) if profiler is not None \
        else contextlib.nullcontext()


def _run_paper(prep: Prepared, verbose: bool, em=NULL,
               profiler=None) -> dict:
    spec, comm = prep.spec, prep.spec.comm
    d, a, r = spec.data, spec.algo, spec.run
    state, key = prep.state, prep.key
    test_accuracy = prep.aux["test_accuracy"]
    record = {"algorithm": a.algorithm, "case": d.case, "dataset": d.dataset,
              "model": prep.aux["model"].name, "rounds": r.rounds,
              "num_workers": d.num_workers, "tau": a.tau, "seed": r.seed,
              "n_params": prep.n_params,
              "eta": np.asarray(prep.aux["eta"]).tolist(),
              "comm": comm._asdict(),
              "payload_bytes_per_worker": payload_bytes(
                  comm, state.global_params),
              "dense_bytes_per_worker": dense_bytes(state.global_params),
              "downlink_bytes_per_worker": payload_bytes(
                  downlink_config(comm), state.global_params),
              "acc": [], "global_loss": [], "selected": [], "delivered": [],
              "uploaded_params": [], "bytes_up": [], "bytes_down": [],
              "airtime_s": [], "energy_j": [], "mean_snr_db": [],
              "round_time_s": []}
    if spec.fleet.population:
        record["population"] = spec.fleet.population
        record["cohort_size"] = d.num_workers
        record["cohort_policy"] = spec.fleet.cohort_policy

    metrics = None
    for t in range(r.rounds):
        t0 = time.time()
        with _round_window(profiler, t):
            with em.span("Step", round_idx=t):
                state, metrics, key = prep.step(state, key)
                if em.active:
                    # host sync so the Step span covers device time;
                    # obs-off runs keep the legacy async dispatch
                    jax.block_until_ready(metrics)
            with em.span("Eval", round_idx=t):
                acc = float(test_accuracy(state.global_params))
        # under fault injection only alive selected workers transmit:
        # the exact byte/energy accounting keys off that count
        transmitted = getattr(metrics, "transmitted", None)
        up, down = host_round_bytes(
            comm,
            selected=(transmitted if transmitted is not None
                      else metrics.selected_count),
            bytes_up_jit=metrics.bytes_up,
            payload_up=record["payload_bytes_per_worker"],
            payload_down=record["downlink_bytes_per_worker"],
            num_workers=d.num_workers)
        # ONE row dict feeds both the artifact history and the event
        # stream, so the JSONL round metrics are bit-equal to the
        # artifact by construction
        row = {"acc": acc, "global_loss": float(metrics.global_loss),
               "selected": int(metrics.selected_count),
               "delivered": int(metrics.delivered_count),
               "uploaded_params": float(metrics.uploaded_params),
               "bytes_up": up, "bytes_down": down,
               "airtime_s": float(metrics.airtime_s),
               "energy_j": float(metrics.energy_j),
               "mean_snr_db": float(metrics.mean_snr_db),
               "round_time_s": round(time.time() - t0, 2)}
        if transmitted is not None:
            row["transmitted"] = int(transmitted)
        for k in ("late", "drained", "buffered", "held"):
            v = getattr(metrics, k, None)
            if v is not None:
                row[k] = int(v)
        if getattr(metrics, "cohort", None) is not None:
            row["cohort"] = np.asarray(metrics.cohort).tolist()
        for k, v in row.items():
            record.setdefault(k, []).append(v)
        em.round(t, row)
        if row.get("held"):
            em.log(f"[straggler] round {t}: quorum hold — w_t frozen "
                   f"(late={row.get('late', 0)} "
                   f"buffered={row.get('buffered', 0)})")
        if verbose and (t % r.log_every == 0 or t == r.rounds - 1):
            em.log(f"[{a.algorithm}/{d.case}/{d.dataset}] "
                   f"round {t + 1}/{r.rounds} "
                   f"acc={acc:.3f} loss={row['global_loss']:.4f} "
                   f"selected={row['selected']}/{d.num_workers} "
                   f"up={float(metrics.bytes_up) / 2**20:.2f}MiB "
                   f"air={row['airtime_s']:.3f}s "
                   f"e={row['energy_j']:.3f}J")
    record["final_acc"] = record["acc"][-1]
    record["best_acc"] = max(record["acc"])
    record["total_uploaded_params"] = float(sum(record["uploaded_params"]))
    record["total_bytes_up"] = float(sum(record["bytes_up"]))
    record["total_bytes_down"] = float(sum(record["bytes_down"]))
    record["total_airtime_s"] = float(sum(record["airtime_s"]))
    record["total_energy_j"] = float(sum(record["energy_j"]))
    # adaptive tiers mix payloads per worker: the fleet-mean ratio comes
    # from the in-jit accounting, matching the bytes_up column
    record["compression_ratio"] = (
        float(metrics.compression_ratio) if comm.adaptive_bits
        else record["dense_bytes_per_worker"]
        / record["payload_bytes_per_worker"])
    return record


# ---------------------------------------------------------------------------
# Mesh driver (production path: reduced assigned arch, jitted SPMD round)
# ---------------------------------------------------------------------------

def _prepare_mesh(spec: ExperimentSpec) -> Prepared:
    from repro.configs.base import get_arch
    from repro.core import swarm_dist
    from repro.core.swarm_dist import DistSwarmConfig
    from repro.models.transformer import Transformer

    m, a, r = spec.model, spec.algo, spec.run
    W = spec.data.num_workers
    cfg = get_arch(m.name)
    if m.reduced:
        cfg = cfg.reduced()
    model = Transformer(cfg)
    dcfg = DistSwarmConfig(worker_axes=(), num_spatial=W,
                           local_steps=a.local_steps, tau=a.tau,
                           hp=a.hp, comm=spec.comm)
    key = jax.random.PRNGKey(r.seed)
    params = model.init(key)
    state = swarm_dist.init_state(params, dcfg)
    build = (swarm_dist.fedavg_train_step if a.algorithm == "fedavg"
             else swarm_dist.build_train_step)
    step_fn = jax.jit(build(model.loss, dcfg))

    B, S = m.per_worker_batch, m.seq_len

    def batch_for(k, lead):
        toks = jax.random.randint(k, lead + (B, S), 0, cfg.vocab_size)
        out = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=-1)}
        if cfg.input_mode == "tokens+prefix":
            out["prefix"] = jnp.zeros(lead + (B, cfg.prefix_len, cfg.d_model),
                                      jnp.dtype(cfg.dtype))
        if cfg.encoder_layers:
            out["frames"] = jax.random.normal(
                k, lead + (B, cfg.encoder_memory_len, cfg.d_model),
                jnp.dtype(cfg.dtype))
        return out

    def step(state, key):
        key, k1, k2, k3 = jax.random.split(key, 4)
        state, info = step_fn(state, batch_for(k1, (W,)), batch_for(k2, ()),
                              k3)
        return state, info, key

    from repro.core import rounds
    return Prepared(spec=spec, state=state, step=step, key=key,
                    n_params=rounds.count_params(params),
                    aux={"model": model, "arch_cfg": cfg, "dcfg": dcfg,
                         "params": params})


def _run_mesh(prep: Prepared, verbose: bool, em=NULL,
              profiler=None) -> dict:
    from repro.checkpoint import CheckpointManager

    spec = prep.spec
    m, r = spec.model, spec.run
    dcfg = prep.aux["dcfg"]
    W = spec.data.num_workers
    state, key = prep.state, prep.key
    mgr = CheckpointManager(r.ckpt_dir) if r.ckpt_dir else None

    payload = payload_bytes(dcfg.comm, prep.aux["params"])
    down_payload = payload_bytes(downlink_config(dcfg.comm),
                                 prep.aux["params"])
    record = {"arch": m.name, "reduced": m.reduced, "steps": r.rounds,
              "comm": dcfg.comm._asdict(),
              "payload_bytes_per_worker": payload,
              "downlink_bytes_per_worker": down_payload, "global_loss": [],
              "worker_losses": [], "selected": [], "delivered": [],
              "bytes_up": [], "bytes_down": [], "airtime_s": [],
              "energy_j": [], "mean_snr_db": [], "step_time_s": []}
    for i in range(r.rounds):
        t0 = time.time()
        with _round_window(profiler, i):
            with em.span("Step", round_idx=i):
                state, info, key = prep.step(state, key)
                if em.active:
                    jax.block_until_ready(info)
        gl = float(info.global_loss)
        transmitted = getattr(info, "transmitted", None)
        up, down = host_round_bytes(
            dcfg.comm,
            selected=(transmitted if transmitted is not None
                      else info.mask.sum()),
            bytes_up_jit=info.bytes_up,
            payload_up=payload, payload_down=down_payload, num_workers=W)
        # one row feeds both artifact history and event stream (see
        # _run_paper) — bit-equal by construction
        row = {"global_loss": gl,
               "worker_losses": np.asarray(info.losses).tolist(),
               "selected": float(info.mask.sum()),
               "delivered": float(info.delivered),
               "bytes_up": up, "bytes_down": down,
               "airtime_s": float(info.airtime_s),
               "energy_j": float(info.energy_j),
               "mean_snr_db": float(info.mean_snr_db),
               "step_time_s": round(time.time() - t0, 2)}
        if transmitted is not None:
            row["transmitted"] = float(transmitted)
        for k in ("late", "drained", "buffered", "held"):
            v = getattr(info, k, None)
            if v is not None:
                row[k] = float(v)
        for k, v in row.items():
            record.setdefault(k, []).append(v)
        em.round(i, row)
        if verbose:
            em.log(f"[mesh/{m.name}] step {i + 1}/{r.rounds} "
                   f"global_loss={gl:.4f} "
                   f"selected={int(info.mask.sum())}/{W} "
                   f"air={row['airtime_s']:.3f}s "
                   f"e={row['energy_j']:.3f}J")
        if mgr is not None:
            mgr.save(i, state.global_params, metadata={"arch": m.name})
    if mgr is not None:
        record["ckpt_steps"] = mgr.all_steps()
    record["total_airtime_s"] = float(sum(record["airtime_s"]))
    record["total_energy_j"] = float(sum(record["energy_j"]))
    return record


# ---------------------------------------------------------------------------
# Facade
# ---------------------------------------------------------------------------

def build(spec: ExperimentSpec) -> Prepared:
    """Validate + materialize a spec into data/model/state and one
    uniform `step` callable, without running any rounds."""
    spec = spec.validate()
    if spec.model.kind != "paper":
        return _prepare_mesh(spec)
    prep = _prepare_paper(spec)
    if spec.fleet.population:
        prep = _wrap_population(prep)
    return prep


def _obs_emitter(spec: ExperimentSpec, engine: str):
    """RunSpec.obs -> an emitter (NULL when disabled). The stream lands
    under `obs.dir` (default artifacts/obs/) as <run_id>.jsonl, plus a
    per-round CSV next to it when `obs.csv` is set."""
    o = spec.run.obs
    if not o.enabled:
        return NULL
    run_id = new_run_id(f"{spec.name or engine}__s{spec.run.seed}")
    base = Path(o.dir) if o.dir else default_obs_dir()
    sink = JsonlSink(base / f"{run_id}.jsonl")
    if o.csv:
        sink = FanoutSink(sink, CsvSink(base / f"{run_id}.csv"))
    return Emitter(run_id, sink)


def _run_totals(record: dict) -> dict:
    """Cumulants for the RunEnd event, read off the finished record."""
    totals = {}
    for k in ("final_acc", "best_acc", "total_bytes_up",
              "total_bytes_down", "total_airtime_s", "total_energy_j"):
        if k in record:
            totals[k] = record[k]
    if "final_acc" not in totals and record.get("global_loss"):
        totals["final_loss"] = record["global_loss"][-1]
    return totals


def run(spec: ExperimentSpec, verbose: bool = True) -> RunResult:
    """Execute a spec end-to-end: the single front door subsuming the
    legacy `run_paper_experiment` / `run_mesh_training` drivers.

    With `run.obs.enabled` the whole run streams typed events (see
    repro.obs): run_start with the full spec, a per-round RoundEvent
    bit-equal to the artifact history, per-stage spans (installed BEFORE
    the first step so the RoundPipeline stages are timed during the
    round-0 jit trace), optional jax.profiler round windows, and a
    run_end with cumulative totals."""
    prep = build(spec)
    spec = prep.spec
    engine = "paper" if spec.model.kind == "paper" else "mesh"
    em = _obs_emitter(spec, engine)
    tracer = profiler = None
    if em.active:
        o = spec.run.obs
        em.run_start(scenario=spec.name, seed=spec.run.seed, engine=engine,
                     num_workers=spec.data.num_workers,
                     rounds=spec.run.rounds, n_params=prep.n_params,
                     population=spec.fleet.population or 0,
                     cohort=(spec.data.num_workers
                             if spec.fleet.population else 0),
                     spec=to_dict(spec))
        if o.stage_spans:
            tracer = obs_trace.StageTracer(em, phase="trace")
        if o.profile_dir:
            profiler = obs_trace.RoundProfiler(
                o.profile_dir, start=min(1, spec.run.rounds - 1),
                count=o.profile_rounds, emitter=em)
    try:
        with obs_trace.activated(tracer):
            record = (_run_paper(prep, verbose, em, profiler)
                      if engine == "paper"
                      else _run_mesh(prep, verbose, em, profiler))
    except BaseException:
        if em.active:
            if profiler is not None:
                profiler.stop()
            em.run_end(rounds=0, status="error")
            em.close()
        raise
    em.run_end(rounds=spec.run.rounds, totals=_run_totals(record))
    em.close()
    return RunResult(spec=spec, record=record, events_path=em.path)


def default_out(spec: ExperimentSpec) -> Path:
    """Artifact path for one run. Scenario runs land under
    artifacts/experiments/<name>__s<seed>.json; anonymous specs keep the
    legacy artifacts/train naming."""
    if spec.run.out:
        return Path(spec.run.out)
    if spec.name:
        safe = spec.name.replace("/", "-")
        return ARTIFACTS / "experiments" / f"{safe}__s{spec.run.seed}.json"
    if spec.model.kind == "paper":
        return (ARTIFACTS / "train" /
                f"{spec.algo.algorithm}__{spec.data.case}"
                f"__{spec.data.dataset}__s{spec.run.seed}.json")
    return (ARTIFACTS / "train" /
            f"mesh__{spec.model.name}__s{spec.run.seed}.json")


def _sweep_task(spec_dict: dict, path: str, verbose: bool) -> dict:
    """One (scenario, seed) cell, spec passed as its JSON dict so the
    task pickles cleanly into a ProcessPoolExecutor worker. Runs the
    spec, saves its artifact, returns {record, events, wall_s}. Obs
    streams are process-local by design (run ids embed the pid), so a
    pool cell needs no cross-process file coordination."""
    from repro.experiments.spec import from_dict
    t0 = time.time()
    res = run(from_dict(spec_dict), verbose=verbose)
    res.save(path)
    return {"record": res.record, "events": res.events_path,
            "wall_s": time.time() - t0}


def _cell_name(spec: ExperimentSpec) -> str:
    return spec.name or f"{spec.algo.algorithm}/{spec.data.case}"


def _sweep_report(spec: ExperimentSpec, record: dict, path: Path,
                  wall_s: float, events: Optional[str]) -> None:
    """Per-cell stderr line: headline metric, wall-time, artifact, and
    (when obs is on) the cell's event stream — grid runs stay
    attributable without re-opening artifacts."""
    final = record.get("final_acc", record["global_loss"][-1])
    ev = f" events={events}" if events else ""
    print(f"[sweep] {_cell_name(spec)} s{spec.run.seed}: {final:.4f} "
          f"wall={wall_s:.1f}s -> {path}{ev}",
          file=sys.stderr, flush=True)


def sweep(specs, seeds=(0,), out_dir: str | Path | None = None,
          verbose: bool = False, jobs: int = 1) -> list[RunResult]:
    """Fan scenarios x seeds into consistently named artifacts, each
    embedding the full spec next to its metrics. Any `run.out` on the
    input specs is cleared: per-(scenario, seed) naming wins, so one
    fixed path cannot clobber the rest of the sweep.

    `jobs > 1` fans the (scenario x seed) grid over a
    ProcessPoolExecutor — each cell is an independent single-host run
    writing its own artifact file, so the paper grid (4 algos x 3 cases
    x 5 seeds) runs in one command (`launch/train.py --sweep ...
    --jobs N`). Results come back in grid order either way."""
    cells: list[tuple[ExperimentSpec, Path]] = []
    for spec in specs:
        for seed in seeds:
            s = override(spec, f"run.seed={seed}", "run.out=none")
            path = default_out(s)
            if out_dir is not None:
                path = Path(out_dir) / path.name
            cells.append((s, path))

    # sweep-level summary stream: one SweepEvent per finished cell (each
    # cell also writes its own run stream) — the grid is derivable from
    # streams alone
    sem = NULL
    if cells and cells[0][0].run.obs.enabled:
        first = cells[0][0]
        base = (Path(first.run.obs.dir) if first.run.obs.dir
                else default_obs_dir())
        rid = new_run_id(f"sweep__{first.name or 'grid'}")
        sem = Emitter(rid, JsonlSink(base / f"{rid}.jsonl"))

    def finish_cell(s, path, record, events, wall_s, results):
        sem.sweep_cell(_cell_name(s), seed=s.run.seed,
                       final=record.get("final_acc",
                                        record["global_loss"][-1]),
                       wall_s=round(wall_s, 3), artifact=str(path),
                       events=events)
        if not verbose:
            _sweep_report(s, record, path, wall_s, events)
        results.append(RunResult(spec=s, record=record,
                                 events_path=events))

    results: list[RunResult] = []
    try:
        if jobs <= 1:
            for s, path in cells:
                t0 = time.time()
                res = run(s, verbose=verbose)
                res.save(path)
                finish_cell(s, path, res.record, res.events_path,
                            time.time() - t0, results)
            return results

        import multiprocessing
        from concurrent.futures import ProcessPoolExecutor

        # fork would copy this process's initialized XLA runtime into
        # the workers (thread-lock deadlocks); spawn gives each cell a
        # clean interpreter
        ctx = multiprocessing.get_context("spawn")
        with ProcessPoolExecutor(max_workers=jobs, mp_context=ctx) as ex:
            futs = [ex.submit(_sweep_task, to_dict(s), str(path), verbose)
                    for s, path in cells]
            for (s, path), fut in zip(cells, futs):
                out = fut.result()
                finish_cell(s, path, out["record"], out["events"],
                            out["wall_s"], results)
        return results
    finally:
        if sem.active:
            sem.run_end(rounds=len(results),
                        status="ok" if len(results) == len(cells)
                        else "error")
            sem.close()


def spec_from_paper_kwargs(algorithm="mdsl", case="noniid1",
                           dataset="mnist_like", rounds=20, num_workers=50,
                           model="cnn", width_mult=8, tau=0.9,
                           local_epochs=4, batch_size=64, lr=0.01,
                           velocity_clip=0.1, seed=0, eta_coeffs=None,
                           n_local=512, log_every=1,
                           comm=None) -> ExperimentSpec:
    """Map the legacy `run_paper_experiment(...)` kwargs onto a spec
    (the deprecated shim and older callers route through this)."""
    from repro.comm.budget import CommConfig
    from repro.core.pso import PsoHyperParams
    from repro.experiments.spec import (AlgoSpec, DataSpec, ModelSpec,
                                        RunSpec)
    return ExperimentSpec(
        data=DataSpec(dataset=dataset, case=case, num_workers=num_workers,
                      n_local=n_local,
                      eta_coeffs=tuple(eta_coeffs) if eta_coeffs else None),
        model=ModelSpec(kind="paper", name=model, width_mult=width_mult),
        algo=AlgoSpec(algorithm=algorithm, tau=tau,
                      local_epochs=local_epochs, batch_size=batch_size,
                      hp=PsoHyperParams(learning_rate=lr,
                                        velocity_clip=velocity_clip)),
        comm=(comm or CommConfig()),
        run=RunSpec(rounds=rounds, seed=seed, log_every=log_every))


def spec_from_mesh_kwargs(arch, steps=5, reduced=True, seq_len=128,
                          per_worker_batch=2, num_spatial=2, ckpt_dir=None,
                          seed=0, comm=None) -> ExperimentSpec:
    """Map the legacy `run_mesh_training(...)` kwargs onto a spec."""
    from repro.comm.budget import CommConfig
    from repro.core.pso import PsoHyperParams
    from repro.experiments.spec import (AlgoSpec, DataSpec, ModelSpec,
                                        RunSpec)
    return ExperimentSpec(
        data=DataSpec(num_workers=num_spatial),
        model=ModelSpec(kind="mesh", name=arch, reduced=reduced,
                        seq_len=seq_len, per_worker_batch=per_worker_batch),
        algo=AlgoSpec(algorithm="mdsl", tau=0.9, local_steps=1,
                      hp=PsoHyperParams(learning_rate=3e-3,
                                        velocity_clip=1.0)),
        comm=(comm or CommConfig()),
        run=RunSpec(rounds=steps, seed=seed,
                    ckpt_dir=str(ckpt_dir) if ckpt_dir else None))


# dataclasses imported for callers composing specs around the runner
__all__ = ["ARTIFACTS", "SCHEMA_VERSION", "Prepared", "RunResult", "build",
           "load_result", "run", "sweep", "default_out", "make_case_data",
           "spec_from_paper_kwargs", "spec_from_mesh_kwargs"]
