"""Declarative, serializable experiment specs (the §V grid, typed).

The paper's evaluation is a grid of algorithm x partition-case x
dataset x comm-channel runs; the related work adds Byzantine and
channel-aware axes on top. `ExperimentSpec` is that grid's single
first-class representation: a frozen dataclass tree

    ExperimentSpec
      ├── data:  DataSpec    dataset / partition case / fleet size
      ├── model: ModelSpec   paper cnn-resnet+width  OR  mesh arch+reduced
      ├── algo:  AlgoSpec    algorithm / tau / epochs / PsoHyperParams
      ├── comm:  CommConfig  the existing repro.comm wire config
      ├── fleet: PopulationSpec  P-device registry / per-round K-cohort
      └── run:   RunSpec     rounds / seed / log cadence / artifact path

with three guarantees every entry point relies on:

  * `spec.validate()` fails fast on any unknown enum value or bad range
    (same checks the CLI used to do by hand, now in one place);
  * `from_dict(to_dict(spec)) == spec` survives a JSON round-trip, so
    every artifact can embed the exact spec that produced it;
  * `override(spec, "comm.compressor=topk")` edits one dotted path with
    type coercion and *rejects unknown paths*, so sweeps are data.

`repro.experiments.registry` names preset specs (the paper figures and
comm regimes); `repro.experiments.runner` executes them.
"""
from __future__ import annotations

import dataclasses
import typing
from typing import Any, Optional

from repro.comm.budget import CommConfig
from repro.core.population import COHORT_POLICIES
from repro.core.pso import PsoHyperParams

SPEC_VERSION = 1

PAPER_DATASETS = ("mnist_like", "cifar_like")
PARTITION_CASES = ("iid", "noniid1", "noniid2")
PAPER_MODELS = ("cnn", "resnet")
PAPER_ALGORITHMS = ("fedavg", "dsl", "multi_dsl", "mdsl")
MESH_ALGORITHMS = ("fedavg", "mdsl")
MODEL_KINDS = ("paper", "mesh")


@dataclasses.dataclass(frozen=True)
class DataSpec:
    """The fleet and its data. For mesh specs `num_workers` is the
    spatial worker count W (dataset/case/n_local are unused: mesh runs
    train on synthetic token batches)."""
    dataset: str = "mnist_like"          # see PAPER_DATASETS
    case: str = "noniid1"                # see PARTITION_CASES
    num_workers: int = 50                # C (paper) / W (mesh)
    n_local: int = 512                   # local samples per worker
    # Dirichlet concentration override for the noniid1 case; None = the
    # paper's 0.5 (heterogeneity sweeps vary this axis directly)
    alpha: Optional[float] = None
    # Eq. 2 coefficients (beta1, beta2, phi); None = dataset default
    eta_coeffs: Optional[tuple[float, float, float]] = None


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """What trains: the paper's image models or an assigned mesh arch."""
    kind: str = "paper"                  # see MODEL_KINDS
    name: str = "cnn"                    # paper: cnn|resnet; mesh: arch name
    width_mult: int = 8                  # paper channel-width multiplier
    reduced: bool = True                 # mesh: CPU smoke-size variant
    seq_len: int = 128                   # mesh token batch shape
    per_worker_batch: int = 2            # mesh token batch shape


@dataclasses.dataclass(frozen=True)
class AlgoSpec:
    """Algorithm 1 and its hyper-parameters."""
    algorithm: str = "mdsl"              # paper: PAPER_ALGORITHMS; mesh:
    #                                      MESH_ALGORITHMS
    tau: float = 0.9                     # Eq. 5 regularizer
    local_epochs: int = 4                # paper local SGD epochs / round
    local_steps: int = 1                 # mesh local SGD steps / round
    batch_size: int = 64                 # paper minibatch size
    hp: PsoHyperParams = PsoHyperParams(learning_rate=0.01,
                                        velocity_clip=0.1)


@dataclasses.dataclass(frozen=True)
class PopulationSpec:
    """The registered-device population behind the per-round cohort
    (core/population.py). `population=None` keeps the legacy full-fleet
    engines (all of data.num_workers train every round). With
    `population=P`, the run models P registered devices at O(P)
    persistent scalars and seats a K = data.num_workers cohort per
    round; `cohort_policy` picks who."""
    population: Optional[int] = None    # P registered devices (None = off)
    cohort_size: Optional[int] = None   # K; must equal data.num_workers
    cohort_policy: str = "uniform"      # see population.COHORT_POLICIES


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """The telemetry bus (repro.obs). Disabled by default: a disabled
    run pays only no-op emitter calls and stays bit-identical to the
    pre-obs goldens."""
    enabled: bool = False
    dir: Optional[str] = None            # stream dir (None = artifacts/obs)
    csv: bool = False                    # also write per-round CSV rows
    stage_spans: bool = True             # trace RoundPipeline stages
    profile_dir: Optional[str] = None    # jax.profiler trace output dir
    profile_rounds: int = 3              # rounds captured per trace window


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """How long, how seeded, where the metrics land."""
    rounds: int = 20                     # communication rounds / mesh steps
    seed: int = 0
    log_every: int = 1                   # verbose print cadence (rounds)
    out: Optional[str] = None            # metrics JSON path (None = default)
    ckpt_dir: Optional[str] = None       # mesh checkpoint directory
    obs: ObsConfig = ObsConfig()         # telemetry bus wiring


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """One cell of the experiment grid, fully self-describing."""
    name: str = ""                       # scenario label (artifact naming)
    data: DataSpec = DataSpec()
    model: ModelSpec = ModelSpec()
    algo: AlgoSpec = AlgoSpec()
    comm: CommConfig = CommConfig()
    fleet: PopulationSpec = PopulationSpec()
    run: RunSpec = RunSpec()

    # -- validation ------------------------------------------------------
    def validate(self) -> "ExperimentSpec":
        m, d, a, r = self.model, self.data, self.algo, self.run
        if m.kind not in MODEL_KINDS:
            raise ValueError(f"unknown model kind {m.kind!r} "
                             f"(choose from {MODEL_KINDS})")
        if m.kind == "paper":
            if m.name not in PAPER_MODELS:
                raise ValueError(f"unknown paper model {m.name!r} "
                                 f"(choose from {PAPER_MODELS})")
            if d.dataset not in PAPER_DATASETS:
                raise ValueError(f"unknown dataset {d.dataset!r} "
                                 f"(choose from {PAPER_DATASETS})")
            if d.case not in PARTITION_CASES:
                raise ValueError(f"unknown partition case {d.case!r} "
                                 f"(choose from {PARTITION_CASES})")
            if a.algorithm not in PAPER_ALGORITHMS:
                raise ValueError(f"unknown algorithm {a.algorithm!r} "
                                 f"(choose from {PAPER_ALGORITHMS})")
        else:
            from repro.configs.base import list_archs
            if a.algorithm not in MESH_ALGORITHMS:
                raise ValueError(f"mesh algorithm must be one of "
                                 f"{MESH_ALGORITHMS}, got {a.algorithm!r}")
            if m.name not in list_archs():
                raise ValueError(f"unknown mesh arch {m.name!r} "
                                 f"(choose from {list_archs()})")
        for fname, v in [("data.num_workers", d.num_workers),
                         ("data.n_local", d.n_local),
                         ("model.width_mult", m.width_mult),
                         ("model.seq_len", m.seq_len),
                         ("model.per_worker_batch", m.per_worker_batch),
                         ("algo.local_epochs", a.local_epochs),
                         ("algo.local_steps", a.local_steps),
                         ("algo.batch_size", a.batch_size),
                         ("run.rounds", r.rounds),
                         ("run.obs.profile_rounds", r.obs.profile_rounds)]:
            if v < 1:
                raise ValueError(f"{fname} must be >= 1, got {v}")
        if not 0.0 <= a.tau <= 1.0:
            raise ValueError(f"algo.tau must be in [0, 1], got {a.tau}")
        # -- fleet: the population/cohort split ---------------------------
        f = self.fleet
        K = d.num_workers                  # per-round cohort size
        if f.cohort_policy not in COHORT_POLICIES:
            raise ValueError(f"unknown fleet.cohort_policy "
                             f"{f.cohort_policy!r} (choose from "
                             f"{COHORT_POLICIES})")
        if f.cohort_size is not None and f.cohort_size != K:
            raise ValueError(
                f"fleet.cohort_size ({f.cohort_size}) must equal "
                f"data.num_workers ({K}) — the cohort seats the engine's "
                f"worker axis; size the round with data.num_workers and "
                f"the registry with fleet.population")
        if f.population is not None:
            if m.kind != "paper":
                raise ValueError(
                    "fleet.population drives the paper engine's sampled-"
                    "cohort wrapper; the mesh path only shards the "
                    "population table (launch/steps.population_specs) — "
                    "unset fleet.population for mesh runs")
            if f.population < K:
                raise ValueError(
                    f"fleet.population ({f.population}) must be >= the "
                    f"per-round cohort size K = data.num_workers ({K})")
        # -- comm robustness bounds: against the per-round cohort size K,
        # not the registry size P (only K uploads aggregate per round) --
        P = f.population or K
        if not 0 <= self.comm.byzantine < K:
            raise ValueError(
                f"comm.byzantine must be in [0, K) where K is the "
                f"per-round cohort size: got byzantine="
                f"{self.comm.byzantine} against K={K} (population P={P}) "
                f"— an all-adversarial cohort trains on attacker updates "
                f"only")
        if (self.comm.aggregator == "trimmed_mean" and self.comm.byzantine
                and int(self.comm.trim_ratio * K) < self.comm.byzantine):
            raise ValueError(
                f"comm.trim_ratio={self.comm.trim_ratio} trims only "
                f"floor(trim_ratio*K) = {int(self.comm.trim_ratio * K)} "
                f"of the K={K} cohort seats per end (population P={P}), "
                f"fewer than comm.byzantine={self.comm.byzantine} "
                f"adversaries — raise trim_ratio or shrink the attack")
        if self.comm.quorum > K:
            raise ValueError(
                f"comm.quorum ({self.comm.quorum}) exceeds the per-round "
                f"cohort size K = data.num_workers ({K}) (population "
                f"P={P}) — at most K deltas (fresh + drained) can ever be "
                f"available, so every round would quorum-hold")
        if d.alpha is not None:
            if d.alpha <= 0.0:
                raise ValueError(f"data.alpha must be > 0, got {d.alpha}")
            if m.kind == "paper" and d.case != "noniid1":
                raise ValueError(
                    f"data.alpha only applies to the noniid1 (Dirichlet) "
                    f"case, not {d.case!r} — unset it or switch case")
        if d.eta_coeffs is not None and len(d.eta_coeffs) != 3:
            raise ValueError("data.eta_coeffs needs exactly "
                             "(beta1, beta2, phi)")
        self.comm.validate()
        return self


# ---------------------------------------------------------------------------
# JSON round-trip
# ---------------------------------------------------------------------------

# struct classes reachable from an ExperimentSpec, keyed for from_dict
_STRUCTS = (ExperimentSpec, DataSpec, ModelSpec, AlgoSpec, RunSpec,
            ObsConfig, PopulationSpec, CommConfig, PsoHyperParams)


def _is_namedtuple(obj: Any) -> bool:
    return isinstance(obj, tuple) and hasattr(obj, "_fields")


def _jsonable(obj: Any) -> Any:
    if dataclasses.is_dataclass(obj):
        return {f.name: _jsonable(getattr(obj, f.name))
                for f in dataclasses.fields(obj)}
    if _is_namedtuple(obj):
        return {k: _jsonable(v) for k, v in obj._asdict().items()}
    if isinstance(obj, (tuple, list)):
        return [_jsonable(v) for v in obj]
    return obj


def to_dict(spec: ExperimentSpec) -> dict:
    """Plain-JSON dict (lists for tuples, nested dicts for sub-specs)."""
    out = _jsonable(spec)
    out["spec_version"] = SPEC_VERSION
    return out


def _field_types(cls: type) -> dict[str, Any]:
    return typing.get_type_hints(cls)


def _struct_for(tp: Any) -> Optional[type]:
    """The struct class named by a (possibly Optional) annotation."""
    for s in _STRUCTS:
        if tp is s:
            return s
    return None


def _unopt(tp: Any) -> Any:
    """Optional[X] -> X (passes everything else through)."""
    if typing.get_origin(tp) is typing.Union:
        args = [a for a in typing.get_args(tp) if a is not type(None)]
        if len(args) == 1:
            return args[0]
    return tp


def _build(cls: type, d: Any) -> Any:
    if not isinstance(d, dict):
        raise ValueError(f"expected a dict for {cls.__name__}, got "
                         f"{type(d).__name__}")
    hints = _field_types(cls)
    unknown = set(d) - set(hints)
    if unknown:
        raise ValueError(f"unknown {cls.__name__} fields: {sorted(unknown)}")
    kw = {}
    for k, v in d.items():
        tp = _unopt(hints[k])
        sub = _struct_for(tp)
        if sub is not None and v is not None:
            v = _build(sub, v)
        elif isinstance(v, list):
            v = tuple(v)
        kw[k] = v
    return cls(**kw)


def from_dict(d: dict) -> ExperimentSpec:
    """Inverse of `to_dict` (tolerates the JSON list/tuple coercion)."""
    d = dict(d)
    d.pop("spec_version", None)
    return _build(ExperimentSpec, d)


# ---------------------------------------------------------------------------
# Dotted-path overrides ("comm.compressor=topk")
# ---------------------------------------------------------------------------

_TRUE = {"1", "true", "yes", "on"}
_FALSE = {"0", "false", "no", "off"}
_NONE = {"none", "null"}


def _coerce(raw: str, tp: Any, path: str) -> Any:
    """Parse a CLI string into the field's annotated type."""
    is_optional = tp is not _unopt(tp)
    tp = _unopt(tp)
    if raw.lower() in _NONE:
        if not is_optional:
            raise ValueError(f"{path}: field is not optional, "
                             f"got {raw!r}")
        return None
    if tp is bool:
        low = raw.lower()
        if low in _TRUE:
            return True
        if low in _FALSE:
            return False
        raise ValueError(f"{path}: expected a boolean, got {raw!r}")
    if tp is int:
        try:
            return int(raw)
        except ValueError:
            raise ValueError(f"{path}: expected an int, got {raw!r}") from None
    if tp is float:
        try:
            return float(raw)
        except ValueError:
            raise ValueError(f"{path}: expected a float, "
                             f"got {raw!r}") from None
    if typing.get_origin(tp) is tuple:
        try:
            return tuple(float(v) for v in raw.split(",") if v.strip())
        except ValueError:
            raise ValueError(f"{path}: expected comma-separated floats, "
                             f"got {raw!r}") from None
    if tp is str:
        return raw
    raise ValueError(f"{path}: cannot parse {raw!r} as {tp}")


def _replace(obj: Any, field: str, value: Any) -> Any:
    if dataclasses.is_dataclass(obj):
        return dataclasses.replace(obj, **{field: value})
    return obj._replace(**{field: value})


def _set_path(obj: Any, keys: list[str], raw: Any, path: str) -> Any:
    if not (dataclasses.is_dataclass(obj) or _is_namedtuple(obj)):
        raise ValueError(f"unknown override path {path!r}: "
                         f"{'.'.join(keys)} is not a spec field")
    hints = _field_types(type(obj))
    k = keys[0]
    if k not in hints:
        raise ValueError(f"unknown override path {path!r}: {k!r} is not a "
                         f"field of {type(obj).__name__} "
                         f"(choose from {sorted(hints)})")
    if len(keys) == 1:
        value = _coerce(raw, hints[k], path) if isinstance(raw, str) else raw
        return _replace(obj, k, value)
    return _replace(obj, k, _set_path(getattr(obj, k), keys[1:], raw, path))


def override(spec: ExperimentSpec, assignment: str,
             *more: str) -> ExperimentSpec:
    """Apply ``"dotted.path=value"`` assignments, returning a new spec.

    Values are coerced to the field's declared type; unknown paths and
    unparsable values raise ValueError (sweeps fail fast, not silently).

        override(spec, "comm.compressor=topk", "run.rounds=2")
    """
    for a in (assignment,) + more:
        path, eq, raw = a.partition("=")
        if not eq:
            raise ValueError(f"override must look like key=value, got {a!r}")
        path = path.strip()
        keys = [k for k in path.split(".") if k]
        if not keys:
            raise ValueError(f"empty override path in {a!r}")
        spec = _set_path(spec, keys, raw.strip(), path)
    return spec
