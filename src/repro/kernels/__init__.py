"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel module trio provides:
  <name>.py — pl.pallas_call with explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (padding, layout, GQA plumbing)
  ref.py    — pure-jnp oracle used by the test sweeps

Kernels: pso_update (the paper's Eq.-8 fused pointwise swarm update),
flash_attention (blockwise causal/sliding attention), rglru_scan
(streaming linear-recurrence scan), quant_pack (fused stochastic
int8/int4 quantize-and-pack for the repro.comm uplink compressors; its
hash-RNG makes the ref.py oracle bit-identical to the kernel). On this
CPU-only container they execute via interpret=True
(`repro.kernels.runtime.interpret_default`) — quant_pack dispatches to
its jnp ref path instead, which is cheaper under the engines' vmap —
and on TPU they compile through Mosaic.
"""
