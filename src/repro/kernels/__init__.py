"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel module trio provides:
  <name>.py — pl.pallas_call with explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (padding, layout, GQA plumbing)
  ref.py    — pure-jnp oracle used by the test sweeps

Kernels: pso_update (the paper's Eq.-8 fused pointwise swarm update),
flash_attention (blockwise causal/sliding attention), rglru_scan
(streaming linear-recurrence scan), and the wire-path pair that fuses
the Eq.-7 uplink hot loop end to end (docs/kernels.md):

  quant_pack  stochastic int8/int4 quantize-and-pack, plus the fused
              quantize+pack+error-feedback-update pass
              (`quantize_pack_ef`: delta + residual -> packed payload,
              block scales, new residual in one read) and the decode
              kernel (`dequantize_unpack`); the shared hash-RNG makes
              the ref.py oracles bit-identical to the kernels
  wire_agg    fused dequant + masked-aggregate: the PS folds C packed
              payloads straight into the Eq.-7 mean / coordinate-wise
              median / trimmed mean without materializing C dense
              reconstructions

On this CPU-only container they execute via interpret=True
(`repro.kernels.runtime.interpret_default`) — the wire-path kernels
dispatch to their jnp ref paths instead, which is cheaper under the
engines' vmap — and on TPU they compile through Mosaic. Every dispatch
decision is reported to the obs bus (`runtime.note_dispatch`).
"""
