"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel module trio provides:
  <name>.py — pl.pallas_call with explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (padding, layout, GQA plumbing)
  ref.py    — pure-jnp oracle used by the test sweeps

Kernels: pso_update (the paper's Eq.-8 fused pointwise swarm update),
flash_attention (blockwise causal/sliding attention), rglru_scan
(streaming linear-recurrence scan). On this CPU-only container they
execute via interpret=True (`repro.kernels.runtime.interpret_default`);
on TPU they compile through Mosaic.
"""
