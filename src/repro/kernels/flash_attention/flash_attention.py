"""Blockwise (flash) attention Pallas kernel — causal / sliding-window,
TPU-native tiling (DESIGN.md §5).

Grid is (batch*heads, num_q_blocks, num_kv_blocks) with the kv dim
iterating fastest (TPU grids are sequential), so the online-softmax
running statistics (m, l) and the output accumulator live in VMEM scratch
across kv steps of one q block:

  * q tile (block_q, head_dim) stays resident in VMEM for the whole kv
    sweep; k/v stream through in (block_k, head_dim) tiles,
  * scores/accumulation in fp32 on the MXU (block_q x block_k x head_dim
    matmuls, all dims 128-multiples),
  * fully-masked kv blocks are skipped via @pl.when on *block indices*
    (causal: block entirely above the diagonal; window: block entirely
    behind the window) — skipped blocks cost no per-element work.

GQA is handled by the ops.py wrapper (kv head replication via reshape of
the BH dim, not materialization). `q_offset` aligns query absolute
positions when Sq < Sk (suffix alignment for chunked prefill).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, block_q: int,
            block_k: int, kv_len: int, q_offset: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    # ---- block-level skip decision (indices only) ------------------------
    q_lo = qi * block_q + q_offset          # absolute position of 1st row
    q_hi = q_lo + block_q - 1
    k_lo = ki * block_k
    k_hi = k_lo + block_k - 1
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_lo <= q_hi)
    if window:
        run = jnp.logical_and(run, k_hi > q_lo - window)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_pos < kv_len
        if causal:
            mask &= k_pos <= q_pos
        if window:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + p.sum(axis=1, keepdims=True)
        acc_scr[...] = (acc_scr[...] * alpha +
                        jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "kv_len", "block_q",
                     "block_k", "interpret"))
def flash_attention_bh(q: jax.Array, k: jax.Array, v: jax.Array, *,
                       causal: bool = True, window: int = 0,
                       q_offset: int = 0, kv_len: int | None = None,
                       block_q: int = DEFAULT_BLOCK_Q,
                       block_k: int = DEFAULT_BLOCK_K,
                       interpret: bool = True) -> jax.Array:
    """q: (BH, Sq, hd), k/v: (BH, Sk, hd); Sq % block_q == Sk % block_k == 0.
    Returns (BH, Sq, hd). Query row i has absolute position q_offset + i;
    kv positions are [0, kv_len) (kv_len < Sk masks right-padding)."""
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    assert Sq % block_q == 0 and Sk % block_k == 0
    scale = 1.0 / math.sqrt(hd)
    grid = (BH, Sq // block_q, Sk // block_k)

    q_spec = pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0))
    kv_spec = pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0))
    o_spec = pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0))

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k,
        kv_len=Sk if kv_len is None else kv_len, q_offset=q_offset)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
