"""Public wrapper for the flash-attention kernel: (B, S, H, hd) layout,
GQA (kv head groups), padding to block multiples."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import runtime
from repro.kernels.flash_attention.flash_attention import (
    DEFAULT_BLOCK_K, DEFAULT_BLOCK_Q, flash_attention_bh)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool | None = None) -> jax.Array:
    """q: (B, Sq, H, hd); k/v: (B, Sk, K, hd) with H % K == 0.
    Suffix-aligned when Sq < Sk (chunked prefill)."""
    if interpret is None:
        interpret = runtime.interpret_default()
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    g = H // K

    bq = min(block_q, max(8, Sq))
    bk = min(block_k, max(8, Sk))
    pq = -Sq % bq
    pk = -Sk % bk
    q_offset = Sk - Sq  # suffix alignment

    # (B, S, H, hd) -> (B*H, S, hd); kv heads repeated to match q heads
    # (XLA fuses the broadcast into the kernel operand stream on TPU).
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kt = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(B * H, Sk, hd)
    vt = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(B * H, Sk, hd)
    if pq:
        qt = jnp.pad(qt, ((0, 0), (0, pq), (0, 0)))
    if pk:
        kt = jnp.pad(kt, ((0, 0), (0, pk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, pk), (0, 0)))

    out = flash_attention_bh(qt, kt, vt, causal=causal, window=window,
                             q_offset=q_offset, kv_len=Sk, block_q=bq,
                             block_k=bk, interpret=interpret)
    out = out[:, :Sq].reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
    return out
