"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  q_offset: int = 0) -> jax.Array:
    """q: (BH, Sq, hd); k/v: (BH, Sk, hd). Dense reference."""
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    q_pos = q_offset + jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[None], p, 0.0)  # rows fully masked -> 0
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
