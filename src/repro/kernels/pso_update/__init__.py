from repro.kernels.pso_update.ops import pso_update
from repro.kernels.pso_update.ref import pso_update_ref
