"""Public wrapper for the fused PSO update kernel: accepts arbitrary
parameter pytrees, flattens + pads to the kernel's (rows, 128) layout,
runs one fused pass, and unflattens. This is the production hot path of
`core/swarm_dist` (per-worker Eq.-8 update over the whole model)."""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import runtime
from repro.kernels.pso_update.pso_update import BLOCK_ROWS, pso_update_2d

PyTree = Any
_LANES = 128


def _flatten_pad(tree: PyTree) -> tuple[jax.Array, Any, int]:
    leaves, treedef = jax.tree.flatten(tree)
    flat = jnp.concatenate([l.reshape(-1) for l in leaves])
    n = flat.shape[0]
    chunk = BLOCK_ROWS * _LANES
    padded = -(-n // chunk) * chunk
    flat = jnp.pad(flat, (0, padded - n))
    return flat.reshape(-1, _LANES), (treedef, [l.shape for l in leaves],
                                      [l.dtype for l in leaves]), n


def _unflatten(flat2d: jax.Array, spec, n: int) -> PyTree:
    treedef, shapes, dtypes = spec
    flat = flat2d.reshape(-1)[:n]
    leaves = []
    off = 0
    for shp, dt in zip(shapes, dtypes):
        size = 1
        for s in shp:
            size *= s
        leaves.append(flat[off:off + size].reshape(shp).astype(dt))
        off += size
    return jax.tree.unflatten(treedef, leaves)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pso_update(params: PyTree, velocity: PyTree, best: PyTree,
               gbest: PyTree, delta: PyTree, c0, c1, c2,
               clip: float = 0.0, *,
               interpret: bool | None = None) -> tuple[PyTree, PyTree]:
    """Fused Eq.-8 update over a whole parameter pytree.

    delta is the accumulated local SGD progress (see core/swarm_dist).
    Returns (new_params, new_velocity) with the input tree structure.
    """
    if interpret is None:
        interpret = runtime.interpret_default()
    coefs = jnp.stack([jnp.asarray(c0, jnp.float32),
                       jnp.asarray(c1, jnp.float32),
                       jnp.asarray(c2, jnp.float32),
                       jnp.asarray(clip, jnp.float32)])
    w2, spec, n = _flatten_pad(jax.tree.map(
        lambda x: x.astype(jnp.float32), params))
    v2, _, _ = _flatten_pad(jax.tree.map(
        lambda x: x.astype(jnp.float32), velocity))
    wl2, _, _ = _flatten_pad(jax.tree.map(
        lambda x: x.astype(jnp.float32), best))
    wg2, _, _ = _flatten_pad(jax.tree.map(
        lambda x: x.astype(jnp.float32), gbest))
    d2, _, _ = _flatten_pad(jax.tree.map(
        lambda x: x.astype(jnp.float32), delta))
    w_new, v_new = pso_update_2d(coefs, w2, v2, wl2, wg2, d2,
                                 interpret=interpret)
    return _unflatten(w_new, spec, n), _unflatten(v_new, spec, n)
