"""Fused PSO-hybrid parameter update kernel (paper Eq. 8).

The M-DSL local update is a 5-in/2-out pointwise stream over the whole
parameter vector:

    v' = c0*v + c1*(wl - w) + c2*(wg - w) + d      (optionally clipped)
    w' = w + v'

Arithmetic intensity ~ 8 flops / 28 bytes (fp32) ≈ 0.29 — firmly
memory-bound, so the win is minimizing HBM traffic: one fused pass reads
5N words and writes 2N, where XLA's unfused graph re-reads intermediates
(9-11N observed from cost_analysis on the swarm step). The kernel tiles
the flattened parameter vector into (8, 128)-aligned VMEM blocks (VPU
lanes; no MXU involved) and streams them.

Coefficients (c0, c1, c2, clip) arrive as a (4,) SMEM operand — they are
per-round scalars sampled on host (paper §V-A).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256          # rows of 128 lanes per grid step => 128 KiB/f32 operand


def _kernel(coef_ref, w_ref, v_ref, wl_ref, wg_ref, d_ref, w_out, v_out):
    c0, c1, c2, clip = (coef_ref[0], coef_ref[1], coef_ref[2], coef_ref[3])
    w = w_ref[...]
    v = v_ref[...]
    v_new = (c0 * v + c1 * (wl_ref[...] - w) + c2 * (wg_ref[...] - w)
             + d_ref[...])
    v_new = jnp.where(clip > 0, jnp.clip(v_new, -clip, clip), v_new)
    v_out[...] = v_new
    w_out[...] = w + v_new


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def pso_update_2d(coefs: jax.Array, w: jax.Array, v: jax.Array,
                  wl: jax.Array, wg: jax.Array, d: jax.Array, *,
                  interpret: bool = True,
                  block_rows: int = BLOCK_ROWS) -> tuple[jax.Array, jax.Array]:
    """Core pallas_call on a (rows, 128) layout. coefs: (4,) f32."""
    rows, lanes = w.shape
    assert lanes == 128 and rows % block_rows == 0, (rows, lanes)
    grid = (rows // block_rows,)
    tile = pl.BlockSpec((block_rows, lanes), lambda i: (i, 0))
    coef_spec = pl.BlockSpec((4,), lambda i: (0,))
    out_shape = (jax.ShapeDtypeStruct(w.shape, w.dtype),
                 jax.ShapeDtypeStruct(v.shape, v.dtype))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[coef_spec] + [tile] * 5,
        out_specs=(tile, tile),
        out_shape=out_shape,
        interpret=interpret,
    )(coefs, w, v, wl, wg, d)
