"""Pure-jnp oracle for the fused PSO update kernel (Eq. 8)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pso_update_ref(coefs: jax.Array, w: jax.Array, v: jax.Array,
                   wl: jax.Array, wg: jax.Array,
                   d: jax.Array) -> tuple[jax.Array, jax.Array]:
    c0, c1, c2, clip = coefs[0], coefs[1], coefs[2], coefs[3]
    v_new = c0 * v + c1 * (wl - w) + c2 * (wg - w) + d
    v_new = jnp.where(clip > 0, jnp.clip(v_new, -clip, clip), v_new)
    return w + v_new, v_new
