from repro.kernels.quant_pack.ops import (dequantize_unpack, quant_dequant,
                                          quantize_pack, quantize_pack_ef)
from repro.kernels.quant_pack.quant_pack import (BLOCK_ROWS, QMAX,
                                                 block_uniform,
                                                 dequant_unpack_2d,
                                                 quant_pack_2d,
                                                 quant_pack_ef_2d)
from repro.kernels.quant_pack.ref import (dequant_unpack_ref,
                                          quant_pack_ef_ref, quant_pack_ref)

__all__ = ["BLOCK_ROWS", "QMAX", "block_uniform", "dequant_unpack_2d",
           "dequant_unpack_ref", "dequantize_unpack", "quant_dequant",
           "quant_pack_2d", "quant_pack_ef_2d", "quant_pack_ef_ref",
           "quant_pack_ref", "quantize_pack", "quantize_pack_ef"]
