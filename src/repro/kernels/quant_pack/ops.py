"""Public wrappers for the quantize-pack kernel family: flatten an
arbitrary array (or pytree leaf) to the kernels' (rows, 128) layout,
produce the packed wire payload + block scales (+ the fused
error-feedback residual), and expose the simulation-friendly
quantize-dequantize round trip used by `repro/comm/compress.py`.

Dispatch: on TPU the fused pallas kernels run compiled; on CPU the
bit-identical ref.py paths run instead (plain jnp — fast under vmap,
same payload bytes). Every wrapper reports its decision via
`runtime.note_dispatch`, so obs streams carry a KernelEvent per
compiled round."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import runtime
from repro.kernels.quant_pack.quant_pack import (BLOCK_ROWS,
                                                 dequant_unpack_2d,
                                                 quant_pack_2d,
                                                 quant_pack_ef_2d)
from repro.kernels.quant_pack.ref import (dequant_unpack_ref,
                                          quant_pack_ef_ref, quant_pack_ref)

_LANES = 128


def _pad_2d(flat: jax.Array) -> jax.Array:
    n = flat.shape[0]
    chunk = BLOCK_ROWS * _LANES
    padded = -(-n // chunk) * chunk
    return jnp.pad(flat, (0, padded - n)).reshape(-1, _LANES)


def quantize_pack(x: jax.Array, seed: jax.Array, *, bits: int = 8,
                  interpret: bool | None = None
                  ) -> tuple[jax.Array, jax.Array]:
    """Pack any-shaped f32 array into the b-bit wire format.
    Returns (packed, scales); `dequantize_unpack(..., shape=x.shape)`
    inverts. interpret=None dispatches by backend (kernel on TPU, ref
    on CPU)."""
    if interpret is None:
        interpret = runtime.interpret_default()
    runtime.note_dispatch("quant_pack", interpret, bits=bits)
    x2 = _pad_2d(x.reshape(-1).astype(jnp.float32))
    if interpret:
        return quant_pack_ref(x2, seed, bits=bits)
    return quant_pack_2d(x2, seed, bits=bits, interpret=False)


def quantize_pack_ef(x: jax.Array, residual: jax.Array, seed: jax.Array, *,
                     bits: int = 8, interpret: bool | None = None
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused uplink hot path: quantize + pack + error-feedback update in
    one pass over x + residual. Returns (packed, scales, new_residual)
    where new_residual = (x + residual) - dequant(packed, scales),
    shaped/dtyped like x (f32) — no dense f32 wire intermediate.

    Payload and scales are bit-identical to the compose
    `quantize_pack(x + residual)` then `dequantize_unpack`; the
    residual is the same subtract but evaluated at the padded block
    shape, so it can differ from a leaf-shape legacy subtract by XLA's
    FMA contraction (<= 1 ulp of acc). Kernel vs ref is bit-identical
    (asserted in tests/test_wire_kernels.py)."""
    if interpret is None:
        interpret = runtime.interpret_default()
    runtime.note_dispatch("quant_pack_ef", interpret, bits=bits)
    x2 = _pad_2d(x.reshape(-1).astype(jnp.float32))
    r2 = _pad_2d(residual.reshape(-1).astype(jnp.float32))
    if interpret:
        packed, scales, res2 = quant_pack_ef_ref(x2, r2, seed, bits=bits)
    else:
        packed, scales, res2 = quant_pack_ef_2d(x2, r2, seed, bits=bits,
                                                interpret=False)
    res = res2.reshape(-1)[: x.size].reshape(x.shape)
    return packed, scales, res


def dequantize_unpack(packed: jax.Array, scales: jax.Array,
                      shape: tuple[int, ...], *, bits: int = 8,
                      dtype=jnp.float32,
                      interpret: bool | None = None) -> jax.Array:
    """Decode a wire payload back to a dense array of `shape`.
    interpret=None dispatches by backend like quantize_pack (this used
    to run the jnp ref unconditionally, leaving the decode half of the
    wire uncompiled on TPU)."""
    if interpret is None:
        interpret = runtime.interpret_default()
    runtime.note_dispatch("dequant_unpack", interpret, bits=bits)
    if interpret:
        x2 = dequant_unpack_ref(packed, scales, bits=bits)
    else:
        x2 = dequant_unpack_2d(packed, scales, bits=bits, interpret=False)
    n = 1
    for s in shape:
        n *= s
    return x2.reshape(-1)[:n].reshape(shape).astype(dtype)


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def quant_dequant(x: jax.Array, seed: jax.Array, *, bits: int = 8,
                  interpret: bool | None = None) -> jax.Array:
    """What the receiver decodes: one fused quantize-pack-unpack round
    trip (the engines' simulation path; byte cost comes from
    `repro.comm.budget.leaf_payload_bytes`)."""
    packed, scales = quantize_pack(x, seed, bits=bits, interpret=interpret)
    return dequantize_unpack(packed, scales, x.shape, bits=bits,
                             dtype=x.dtype, interpret=interpret)
