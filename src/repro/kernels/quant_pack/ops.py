"""Public wrapper for the quantize-pack kernel: flattens an arbitrary
array (or pytree leaf) to the kernel's (rows, 128) layout, produces the
packed wire payload + block scales, and exposes the simulation-friendly
quantize-dequantize round trip used by `repro/comm/compress.py`.

Dispatch: on TPU the fused pallas kernel runs compiled; on CPU the
bit-identical ref.py path runs instead (plain jnp — fast under vmap,
same payload bytes)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import runtime
from repro.kernels.quant_pack.quant_pack import (BLOCK_ROWS, quant_pack_2d)
from repro.kernels.quant_pack.ref import dequant_unpack_ref, quant_pack_ref

_LANES = 128


def _pad_2d(flat: jax.Array) -> jax.Array:
    n = flat.shape[0]
    chunk = BLOCK_ROWS * _LANES
    padded = -(-n // chunk) * chunk
    return jnp.pad(flat, (0, padded - n)).reshape(-1, _LANES)


def quantize_pack(x: jax.Array, seed: jax.Array, *, bits: int = 8,
                  interpret: bool | None = None
                  ) -> tuple[jax.Array, jax.Array]:
    """Pack any-shaped f32 array into the b-bit wire format.
    Returns (packed, scales); `dequantize_unpack(..., shape=x.shape)`
    inverts. interpret=None dispatches by backend (kernel on TPU, ref
    on CPU)."""
    if interpret is None:
        interpret = runtime.interpret_default()
    runtime.note_dispatch("quant_pack", interpret, bits=bits)
    x2 = _pad_2d(x.reshape(-1).astype(jnp.float32))
    if interpret:
        return quant_pack_ref(x2, seed, bits=bits)
    return quant_pack_2d(x2, seed, bits=bits, interpret=False)


def dequantize_unpack(packed: jax.Array, scales: jax.Array,
                      shape: tuple[int, ...], *, bits: int = 8,
                      dtype=jnp.float32) -> jax.Array:
    """Decode a wire payload back to a dense array of `shape`."""
    x2 = dequant_unpack_ref(packed, scales, bits=bits)
    n = 1
    for s in shape:
        n *= s
    return x2.reshape(-1)[:n].reshape(shape).astype(dtype)


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def quant_dequant(x: jax.Array, seed: jax.Array, *, bits: int = 8,
                  interpret: bool | None = None) -> jax.Array:
    """What the receiver decodes: one fused quantize-pack-unpack round
    trip (the engines' simulation path; byte cost comes from
    `repro.comm.budget.leaf_payload_bytes`)."""
    packed, scales = quantize_pack(x, seed, bits=bits, interpret=interpret)
    return dequantize_unpack(packed, scales, x.shape, bits=bits,
                             dtype=x.dtype)
