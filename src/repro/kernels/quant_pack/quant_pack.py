"""Fused stochastic quantize-and-pack kernels (uplink wire format).

The int8/int4 uplink compressors (`repro/comm/compress.py`) reduce a
worker's round delta to b-bit integers plus one f32 scale per block.
Unfused, XLA materializes |x|, the block max, the scaled tensor, the
random field, and the rounded tensor as separate HBM round-trips; the
payload is produced in one pass here: each grid step reads one
(BLOCK_ROWS, 128) f32 tile from VMEM and emits the packed integer tile
plus its scale (read N f32 words, write N*b/32 + 1).

Three kernels share the block math:

  quant_pack_2d     quantize + pack              (x -> packed, scales)
  quant_pack_ef_2d  quantize + pack + error-feedback update in ONE pass
                    (delta, residual -> packed, scales, new residual =
                    acc - dequant(q)) — the uplink hot loop, no dense
                    f32 round-trip between compression and EF
  dequant_unpack_2d packed, scales -> dense f32  (the decode half; the
                    PS-side aggregate fuses this further, see
                    kernels/wire_agg)

Layout: the flattened parameter vector is tiled to (rows, 128) like
`pso_update`. int8 packs 1:1 into an int8 tile; int4 packs two rows per
byte — row r of the output holds rows r (low nibble) and r + B/2 (high
nibble) of the block — keeping the 128-lane minor dim intact for TPU
tiling (nibble-within-lane packing would shrink the minor dim to 64).

Stochastic rounding uses a counter-based integer hash (`block_uniform`)
seeded per call: pure uint32 jnp arithmetic, so the same bits are
produced by the compiled Mosaic kernel, interpret mode, and the ref.py
oracle — exact-equality tests and bit-identical CPU/TPU simulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256          # (256, 128) f32 tile = 128 KiB VMEM per operand
_LANES = 128

QMAX = {8: 127.0, 4: 7.0}


def block_uniform(seed: jax.Array, block_idx: jax.Array,
                  shape: tuple[int, int]) -> jax.Array:
    """U[0,1) field for one block: a splitmix-style uint32 hash of
    (seed, block, row, lane). Part of the wire spec — ref.py reuses it so
    packed payloads are bit-identical across backends."""
    r = jax.lax.broadcasted_iota(jnp.uint32, shape, 0)
    c = jax.lax.broadcasted_iota(jnp.uint32, shape, 1)
    h = (seed.astype(jnp.uint32) * jnp.uint32(2654435761)
         + block_idx.astype(jnp.uint32) * jnp.uint32(976686449)
         + r * jnp.uint32(1664525) + c * jnp.uint32(22695477))
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x7FEB352D)
    h = h ^ (h >> 15)
    h = h * jnp.uint32(0x846CA68B)
    h = h ^ (h >> 16)
    return (h >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def _quantize_block(x: jax.Array, seed: jax.Array, block_idx: jax.Array,
                    qmax: float) -> tuple[jax.Array, jax.Array]:
    """Shared math: per-block scale + unbiased stochastic rounding.
    Returns (q f32 in [-qmax, qmax], scale f32).

    scale is amax * (1/qmax), NOT amax / qmax: XLA strength-reduces a
    divide-by-constant to a reciprocal multiply but interpret mode does
    not, and the 1-ulp drift would break kernel/ref bit-equality."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0.0, amax * jnp.float32(1.0 / qmax), 1.0)
    u = block_uniform(seed, block_idx, x.shape)
    q = jnp.clip(jnp.floor(x / scale + u), -qmax, qmax)
    return q, scale


def _pack_nibbles(q: jax.Array) -> jax.Array:
    """(..., B, 128) integral f32 in [-7, 7] -> (..., B/2, 128) uint8.
    Output row r holds rows r (low nibble) and r + B/2 (high nibble).

    The bit ops run in int32 and cast to uint8 only at the end: Mosaic
    has no uint8 shift/or lowering (sub-word vectors only support
    widen/narrow), so the original uint8 formulation ran in interpret
    mode only. Values are exact small ints, so the int32 route is
    bit-identical."""
    half = q.shape[-2] // 2
    biased = (q + 8.0).astype(jnp.int32)         # [-7,7] -> [1,15]
    packed = biased[..., :half, :] | (biased[..., half:, :] << 4)
    return packed.astype(jnp.uint8)


def _unpack_nibbles(packed: jax.Array) -> jax.Array:
    """Inverse of _pack_nibbles: (..., B/2, 128) uint8 -> (..., B, 128)
    f32 in [-7, 7]. Same int32 discipline (widen first, then bit ops)."""
    p = packed.astype(jnp.int32)
    lo = ((p & 0xF) - 8).astype(jnp.float32)
    hi = ((p >> 4) - 8).astype(jnp.float32)
    return jnp.concatenate([lo, hi], axis=-2)


def _kernel_int8(seed_ref, x_ref, q_ref, scale_ref):
    q, scale = _quantize_block(x_ref[...], seed_ref[0],
                               pl.program_id(0), QMAX[8])
    q_ref[...] = q.astype(jnp.int8)
    scale_ref[0] = scale


def _kernel_int4(seed_ref, x_ref, q_ref, scale_ref):
    q, scale = _quantize_block(x_ref[...], seed_ref[0],
                               pl.program_id(0), QMAX[4])
    q_ref[...] = _pack_nibbles(q)
    scale_ref[0] = scale


@functools.partial(jax.jit,
                   static_argnames=("bits", "interpret", "block_rows"))
def quant_pack_2d(x: jax.Array, seed: jax.Array, *, bits: int = 8,
                  interpret: bool = True,
                  block_rows: int = BLOCK_ROWS
                  ) -> tuple[jax.Array, jax.Array]:
    """Core pallas_call on a (rows, 128) f32 layout.

    Returns (packed, scales): packed is int8 (rows, 128) for bits=8 or
    uint8 (rows//2, 128) for bits=4; scales is (rows // block_rows,) f32.
    """
    rows, lanes = x.shape
    assert lanes == _LANES and rows % block_rows == 0, (rows, lanes)
    assert bits in (8, 4), bits
    grid = (rows // block_rows,)
    tile = pl.BlockSpec((block_rows, lanes), lambda i: (i, 0))
    seed_spec = pl.BlockSpec((1,), lambda i: (0,))
    scale_spec = pl.BlockSpec((1,), lambda i: (i,))
    if bits == 8:
        kernel = _kernel_int8
        q_spec = tile
        q_shape = jax.ShapeDtypeStruct((rows, lanes), jnp.int8)
    else:
        kernel = _kernel_int4
        q_spec = pl.BlockSpec((block_rows // 2, lanes), lambda i: (i, 0))
        q_shape = jax.ShapeDtypeStruct((rows // 2, lanes), jnp.uint8)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[seed_spec, tile],
        out_specs=(q_spec, scale_spec),
        out_shape=(q_shape,
                   jax.ShapeDtypeStruct((rows // block_rows,), jnp.float32)),
        interpret=interpret,
    )(jnp.asarray(seed, jnp.int32).reshape(1), x)


def _make_ef_kernel(bits: int):
    qmax = QMAX[bits]

    def kernel(seed_ref, x_ref, r_ref, q_ref, scale_ref, res_ref):
        acc = x_ref[...] + r_ref[...]            # EF carry folded in VMEM
        q, scale = _quantize_block(acc, seed_ref[0], pl.program_id(0), qmax)
        q_ref[...] = q.astype(jnp.int8) if bits == 8 else _pack_nibbles(q)
        scale_ref[0] = scale
        # q is exactly what the receiver unpacks (the int round trip is
        # lossless), so acc - q*scale IS acc - dequant(packed) bit-for-bit
        res_ref[...] = acc - q * scale

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("bits", "interpret", "block_rows"))
def quant_pack_ef_2d(x: jax.Array, residual: jax.Array, seed: jax.Array, *,
                     bits: int = 8, interpret: bool = True,
                     block_rows: int = BLOCK_ROWS
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused uplink pass on (rows, 128) f32 layouts: one grid step reads
    a delta tile + its error-feedback residual tile and emits the packed
    wire tile, the block scale, and the NEW residual tile — the legacy
    compress -> dequant -> subtract chain without the dense f32
    round-trip (reads 8 bytes/elem, writes 4 + b/8 instead of the
    unfused ~36 + b/4; see docs/kernels.md).

    Returns (packed, scales, new_residual); packed/scales exactly as
    `quant_pack_2d(x + residual, seed)`, new_residual f32 like x."""
    rows, lanes = x.shape
    assert x.shape == residual.shape, (x.shape, residual.shape)
    assert lanes == _LANES and rows % block_rows == 0, (rows, lanes)
    assert bits in (8, 4), bits
    grid = (rows // block_rows,)
    tile = pl.BlockSpec((block_rows, lanes), lambda i: (i, 0))
    seed_spec = pl.BlockSpec((1,), lambda i: (0,))
    scale_spec = pl.BlockSpec((1,), lambda i: (i,))
    if bits == 8:
        q_spec = tile
        q_shape = jax.ShapeDtypeStruct((rows, lanes), jnp.int8)
    else:
        q_spec = pl.BlockSpec((block_rows // 2, lanes), lambda i: (i, 0))
        q_shape = jax.ShapeDtypeStruct((rows // 2, lanes), jnp.uint8)
    return pl.pallas_call(
        _make_ef_kernel(bits),
        grid=grid,
        in_specs=[seed_spec, tile, tile],
        out_specs=(q_spec, scale_spec, tile),
        out_shape=(q_shape,
                   jax.ShapeDtypeStruct((rows // block_rows,), jnp.float32),
                   jax.ShapeDtypeStruct((rows, lanes), jnp.float32)),
        interpret=interpret,
    )(jnp.asarray(seed, jnp.int32).reshape(1), x, residual)


def _make_dequant_kernel(bits: int):
    def kernel(scale_ref, q_ref, x_ref):
        q = (q_ref[...].astype(jnp.float32) if bits == 8
             else _unpack_nibbles(q_ref[...]))
        x_ref[...] = q * scale_ref[0]

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("bits", "interpret", "block_rows"))
def dequant_unpack_2d(packed: jax.Array, scales: jax.Array, *,
                      bits: int = 8, interpret: bool = True,
                      block_rows: int = BLOCK_ROWS) -> jax.Array:
    """Decode kernel: packed (rows, 128) int8 / (rows/2, 128) uint8 plus
    per-block scales -> dense (rows, 128) f32. Inverse of the pack half
    of quant_pack_2d / quant_pack_ef_2d."""
    lanes = packed.shape[1]
    rows = packed.shape[0] * (2 if bits == 4 else 1)
    assert lanes == _LANES and rows % block_rows == 0, packed.shape
    assert bits in (8, 4), bits
    grid = (rows // block_rows,)
    pb = block_rows // (2 if bits == 4 else 1)
    return pl.pallas_call(
        _make_dequant_kernel(bits),
        grid=grid,
        in_specs=[pl.BlockSpec((1,), lambda i: (i,)),
                  pl.BlockSpec((pb, lanes), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, lanes), jnp.float32),
        interpret=interpret,
    )(scales, packed)
