"""Fused stochastic quantize-and-pack kernel (uplink compression).

The int8/int4 uplink compressors (`repro/comm/compress.py`) reduce a
worker's round delta to b-bit integers plus one f32 scale per block.
Unfused, XLA materializes |x|, the block max, the scaled tensor, the
random field, and the rounded tensor as separate HBM round-trips; the
payload is produced in one pass here: each grid step reads one
(BLOCK_ROWS, 128) f32 tile from VMEM and emits the packed integer tile
plus its scale (read N f32 words, write N*b/32 + 1).

Layout: the flattened parameter vector is tiled to (rows, 128) like
`pso_update`. int8 packs 1:1 into an int8 tile; int4 packs two rows per
byte — row r of the output holds rows r (low nibble) and r + B/2 (high
nibble) of the block — keeping the 128-lane minor dim intact for TPU
tiling (nibble-within-lane packing would shrink the minor dim to 64).

Stochastic rounding uses a counter-based integer hash (`block_uniform`)
seeded per call: pure uint32 jnp arithmetic, so the same bits are
produced by the compiled Mosaic kernel, interpret mode, and the ref.py
oracle — exact-equality tests and bit-identical CPU/TPU simulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256          # (256, 128) f32 tile = 128 KiB VMEM per operand
_LANES = 128

QMAX = {8: 127.0, 4: 7.0}


def block_uniform(seed: jax.Array, block_idx: jax.Array,
                  shape: tuple[int, int]) -> jax.Array:
    """U[0,1) field for one block: a splitmix-style uint32 hash of
    (seed, block, row, lane). Part of the wire spec — ref.py reuses it so
    packed payloads are bit-identical across backends."""
    r = jax.lax.broadcasted_iota(jnp.uint32, shape, 0)
    c = jax.lax.broadcasted_iota(jnp.uint32, shape, 1)
    h = (seed.astype(jnp.uint32) * jnp.uint32(2654435761)
         + block_idx.astype(jnp.uint32) * jnp.uint32(976686449)
         + r * jnp.uint32(1664525) + c * jnp.uint32(22695477))
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x7FEB352D)
    h = h ^ (h >> 15)
    h = h * jnp.uint32(0x846CA68B)
    h = h ^ (h >> 16)
    return (h >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def _quantize_block(x: jax.Array, seed: jax.Array, block_idx: jax.Array,
                    qmax: float) -> tuple[jax.Array, jax.Array]:
    """Shared math: per-block scale + unbiased stochastic rounding.
    Returns (q f32 in [-qmax, qmax], scale f32).

    scale is amax * (1/qmax), NOT amax / qmax: XLA strength-reduces a
    divide-by-constant to a reciprocal multiply but interpret mode does
    not, and the 1-ulp drift would break kernel/ref bit-equality."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0.0, amax * jnp.float32(1.0 / qmax), 1.0)
    u = block_uniform(seed, block_idx, x.shape)
    q = jnp.clip(jnp.floor(x / scale + u), -qmax, qmax)
    return q, scale


def _kernel_int8(seed_ref, x_ref, q_ref, scale_ref):
    q, scale = _quantize_block(x_ref[...], seed_ref[0],
                               pl.program_id(0), QMAX[8])
    q_ref[...] = q.astype(jnp.int8)
    scale_ref[0] = scale


def _kernel_int4(seed_ref, x_ref, q_ref, scale_ref):
    q, scale = _quantize_block(x_ref[...], seed_ref[0],
                               pl.program_id(0), QMAX[4])
    half = q.shape[0] // 2
    biased = (q + 8.0).astype(jnp.uint8)        # [-7,7] -> [1,15]
    q_ref[...] = biased[:half] | (biased[half:] << 4)
    scale_ref[0] = scale


@functools.partial(jax.jit,
                   static_argnames=("bits", "interpret", "block_rows"))
def quant_pack_2d(x: jax.Array, seed: jax.Array, *, bits: int = 8,
                  interpret: bool = True,
                  block_rows: int = BLOCK_ROWS
                  ) -> tuple[jax.Array, jax.Array]:
    """Core pallas_call on a (rows, 128) f32 layout.

    Returns (packed, scales): packed is int8 (rows, 128) for bits=8 or
    uint8 (rows//2, 128) for bits=4; scales is (rows // block_rows,) f32.
    """
    rows, lanes = x.shape
    assert lanes == _LANES and rows % block_rows == 0, (rows, lanes)
    assert bits in (8, 4), bits
    grid = (rows // block_rows,)
    tile = pl.BlockSpec((block_rows, lanes), lambda i: (i, 0))
    seed_spec = pl.BlockSpec((1,), lambda i: (0,))
    scale_spec = pl.BlockSpec((1,), lambda i: (i,))
    if bits == 8:
        kernel = _kernel_int8
        q_spec = tile
        q_shape = jax.ShapeDtypeStruct((rows, lanes), jnp.int8)
    else:
        kernel = _kernel_int4
        q_spec = pl.BlockSpec((block_rows // 2, lanes), lambda i: (i, 0))
        q_shape = jax.ShapeDtypeStruct((rows // 2, lanes), jnp.uint8)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[seed_spec, tile],
        out_specs=(q_spec, scale_spec),
        out_shape=(q_shape,
                   jax.ShapeDtypeStruct((rows // block_rows,), jnp.float32)),
        interpret=interpret,
    )(jnp.asarray(seed, jnp.int32).reshape(1), x)
