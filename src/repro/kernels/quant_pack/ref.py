"""Pure-jnp oracle for the fused quantize-pack kernel.

Also the CPU fallback for `repro/comm/compress.py`: it implements the
identical block layout, scale rule, and hash-RNG rounding (shared via
`block_uniform`), so payloads are bit-identical to the kernel while
staying plain jnp — cheap under the engines' vmap over workers, where
interpret-mode pallas would be needlessly slow.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.quant_pack.quant_pack import (BLOCK_ROWS, QMAX,
                                                 _quantize_block)


def quant_pack_ref(x: jax.Array, seed: jax.Array, *, bits: int = 8,
                   block_rows: int = BLOCK_ROWS
                   ) -> tuple[jax.Array, jax.Array]:
    """Matches quant_pack_2d bit-exactly: vmaps the kernel's per-block
    math (same reduction order — a stacked jnp.max over all blocks can
    differ by 1 ulp). x: (rows, 128) f32, rows a multiple of block_rows.
    Returns (packed, scales)."""
    rows, lanes = x.shape
    assert lanes == 128 and rows % block_rows == 0, (rows, lanes)
    nb = rows // block_rows
    qmax = QMAX[bits]
    xb = x.reshape(nb, block_rows, lanes)
    seed = jnp.asarray(seed, jnp.int32)

    # unrolled per-block loop, NOT a vmap: XLA lowers a batched max with
    # a different reduction order than the kernel's per-block max, which
    # shifts scales by 1 ulp and breaks bit-equality
    per_block = [
        _quantize_block(xb[i], seed, jnp.int32(i), qmax) for i in range(nb)]
    q = jnp.stack([p[0] for p in per_block])
    scales = jnp.stack([p[1] for p in per_block])
    if bits == 8:
        return q.astype(jnp.int8).reshape(rows, lanes), scales
    half = block_rows // 2
    biased = (q + 8.0).astype(jnp.uint8)
    packed = biased[:, :half] | (biased[:, half:] << 4)
    return packed.reshape(rows // 2, lanes), scales


def dequant_unpack_ref(packed: jax.Array, scales: jax.Array, *,
                       bits: int = 8,
                       block_rows: int = BLOCK_ROWS) -> jax.Array:
    """Inverse of quant_pack_ref (up to rounding): (rows, 128) f32."""
    lanes = packed.shape[1]
    if bits == 8:
        rows = packed.shape[0]
        q = packed.astype(jnp.float32)
    else:
        rows = packed.shape[0] * 2
        half = block_rows // 2
        pb = packed.reshape(-1, half, lanes)
        lo = (pb & 0xF).astype(jnp.float32) - 8.0
        hi = (pb >> 4).astype(jnp.float32) - 8.0
        q = jnp.concatenate([lo, hi], axis=1)
    qb = q.reshape(rows // block_rows, block_rows, lanes)
    return (qb * scales[:, None, None]).reshape(rows, lanes)
