"""Pure-jnp oracles for the quantize-pack kernel family.

Also the CPU fallback for `repro/comm/compress.py`: they implement the
identical block layout, scale rule, hash-RNG rounding (shared via
`block_uniform`) and nibble packing (shared `_pack_nibbles` /
`_unpack_nibbles`), so payloads and residuals are bit-identical to the
kernels while staying plain jnp — cheap under the engines' vmap over
workers, where interpret-mode pallas would be needlessly slow.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.quant_pack.quant_pack import (BLOCK_ROWS, QMAX,
                                                 _pack_nibbles,
                                                 _quantize_block,
                                                 _unpack_nibbles)


def quant_pack_ref(x: jax.Array, seed: jax.Array, *, bits: int = 8,
                   block_rows: int = BLOCK_ROWS
                   ) -> tuple[jax.Array, jax.Array]:
    """Matches quant_pack_2d bit-exactly: unrolls the kernel's per-block
    math (same reduction order — a stacked jnp.max over all blocks can
    differ by 1 ulp). x: (rows, 128) f32, rows a multiple of block_rows.
    Returns (packed, scales)."""
    rows, lanes = x.shape
    assert lanes == 128 and rows % block_rows == 0, (rows, lanes)
    nb = rows // block_rows
    qmax = QMAX[bits]
    xb = x.reshape(nb, block_rows, lanes)
    seed = jnp.asarray(seed, jnp.int32)

    # unrolled per-block loop, NOT a vmap: XLA lowers a batched max with
    # a different reduction order than the kernel's per-block max, which
    # shifts scales by 1 ulp and breaks bit-equality
    per_block = [
        _quantize_block(xb[i], seed, jnp.int32(i), qmax) for i in range(nb)]
    q = jnp.stack([p[0] for p in per_block])
    scales = jnp.stack([p[1] for p in per_block])
    if bits == 8:
        return q.astype(jnp.int8).reshape(rows, lanes), scales
    return _pack_nibbles(q).reshape(rows // 2, lanes), scales


@functools.partial(jax.jit, static_argnames=("bits", "block_rows"))
def quant_pack_ef_ref(x: jax.Array, residual: jax.Array, seed: jax.Array, *,
                      bits: int = 8, block_rows: int = BLOCK_ROWS
                      ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Oracle for quant_pack_ef_2d: per block, acc = x + residual, then
    the shared quantize math, then new_residual = acc - q*scale (== acc
    - dequant(packed), the int round trip is lossless). Bit-identical to
    the fused kernel AND to the legacy compose
    quant_pack_ref(x + residual) / dequant_unpack_ref / subtract —
    *under jit*: the def-site jit keeps the residual's multiply-subtract
    on the compiled (FMA-fused) path even when called eagerly, matching
    the always-jitted kernel and the jitted engine rounds."""
    rows, lanes = x.shape
    assert x.shape == residual.shape, (x.shape, residual.shape)
    assert lanes == 128 and rows % block_rows == 0, (rows, lanes)
    nb = rows // block_rows
    qmax = QMAX[bits]
    xb = x.reshape(nb, block_rows, lanes)
    rb = residual.reshape(nb, block_rows, lanes)
    seed = jnp.asarray(seed, jnp.int32)

    qs, scs, ress = [], [], []
    for i in range(nb):                          # unrolled: see above
        acc = xb[i] + rb[i]
        q, scale = _quantize_block(acc, seed, jnp.int32(i), qmax)
        qs.append(q)
        scs.append(scale)
        ress.append(acc - q * scale)
    q = jnp.stack(qs)
    scales = jnp.stack(scs)
    res = jnp.stack(ress).reshape(rows, lanes)
    if bits == 8:
        return q.astype(jnp.int8).reshape(rows, lanes), scales, res
    return _pack_nibbles(q).reshape(rows // 2, lanes), scales, res


def dequant_unpack_ref(packed: jax.Array, scales: jax.Array, *,
                       bits: int = 8,
                       block_rows: int = BLOCK_ROWS) -> jax.Array:
    """Inverse of quant_pack_ref (up to rounding): (rows, 128) f32."""
    lanes = packed.shape[1]
    if bits == 8:
        rows = packed.shape[0]
        q = packed.astype(jnp.float32)
    else:
        rows = packed.shape[0] * 2
        half = block_rows // 2
        q = _unpack_nibbles(packed.reshape(-1, half, lanes))
    qb = q.reshape(rows // block_rows, block_rows, lanes)
    return (qb * scales[:, None, None]).reshape(rows, lanes)
