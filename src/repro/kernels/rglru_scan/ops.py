"""Public wrapper for the linear-recurrence scan kernel: pads the
sequence to a block multiple (appending identity steps a=1, b=0 keeps the
carried state exact) and returns states + final carry."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import runtime
from repro.kernels.rglru_scan.rglru_scan import (DEFAULT_BLOCK_S,
                                                 rglru_scan_raw)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def rglru_scan(h0: jax.Array, a: jax.Array, b: jax.Array, *,
               block_s: int = DEFAULT_BLOCK_S,
               interpret: bool | None = None
               ) -> tuple[jax.Array, jax.Array]:
    """h0: (B, D); a, b: (B, S, D). Returns (states (B,S,D) f32,
    final state (B,D) f32)."""
    if interpret is None:
        interpret = runtime.interpret_default()
    B, S, D = a.shape
    bs = min(block_s, max(8, S))
    pad = -S % bs
    if pad:
        a = jnp.pad(a.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)),
                    constant_values=1.0)
        b = jnp.pad(b.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    states = rglru_scan_raw(h0.astype(jnp.float32), a.astype(jnp.float32),
                            b.astype(jnp.float32), block_s=bs,
                            interpret=interpret)
    final = states[:, S - 1]  # identity padding keeps the carry constant
    return states[:, :S], final
