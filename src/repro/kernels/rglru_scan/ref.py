"""Pure-jnp oracle for the linear-recurrence scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(h0: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """Sequential reference: h_t = a_t h_{t-1} + b_t. Shapes as kernel."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h
    _, hs = jax.lax.scan(step, h0.astype(jnp.float32),
                         (a.swapaxes(0, 1).astype(jnp.float32),
                          b.swapaxes(0, 1).astype(jnp.float32)))
    return hs.swapaxes(0, 1)
