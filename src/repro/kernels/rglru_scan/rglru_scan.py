"""Streaming linear-recurrence scan kernel (RG-LRU / diagonal SSM core).

Computes h_t = a_t * h_{t-1} + b_t over the sequence for every (batch,
channel) lane — the inner recurrence of RecurrentGemma's RG-LRU block
(models/recurrent.py) after the gates have produced a and b.

TPU adaptation (DESIGN.md §5): recurrences are the systolic array's weak
spot, so the kernel blocks the sequence — grid (batch, num_seq_blocks)
with the seq dim iterating sequentially; the carried state h lives in a
(1, D) fp32 VMEM scratch across blocks, and within a block the time loop
is a fori_loop over rows that are fully vectorized across the 128-lane
channel dim. HBM traffic is the theoretical minimum (read a, b once,
write h once); XLA's associative_scan alternative is log-depth but moves
O(S log S) intermediate data through HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_S = 256


def _kernel(h0_ref, a_ref, b_ref, out_ref, h_scr, *, block_s: int):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        h_scr[...] = h0_ref[...]

    a = a_ref[0]            # (block_s, D)
    b = b_ref[0]

    def step(t, h):
        h = a[t] * h + b[t]
        out_ref[0, t] = h.astype(out_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_s, step, h_scr[0])
    h_scr[...] = h[None]


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def rglru_scan_raw(h0: jax.Array, a: jax.Array, b: jax.Array, *,
                   block_s: int = DEFAULT_BLOCK_S,
                   interpret: bool = True) -> jax.Array:
    """h0: (B, D); a, b: (B, S, D) with S % block_s == 0. Returns states
    (B, S, D) where out[:, t] = a[:,t]*out[:,t-1] + b[:,t] (out[:,-1]=h0)."""
    B, S, D = a.shape
    assert S % block_s == 0
    grid = (B, S // block_s)
    seq_spec = pl.BlockSpec((1, block_s, D), lambda i, j: (i, j, 0))
    h0_spec = pl.BlockSpec((1, D), lambda i, j: (i, 0))
    return pl.pallas_call(
        functools.partial(_kernel, block_s=block_s),
        grid=grid,
        in_specs=[h0_spec, seq_spec, seq_spec],
        out_specs=seq_spec,
        out_shape=jax.ShapeDtypeStruct((B, S, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, D), jnp.float32)],
        interpret=interpret,
    )(h0, a, b)
