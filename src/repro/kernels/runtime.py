"""Kernel runtime switches."""
import jax


def interpret_default() -> bool:
    """Pallas interpret mode: True on CPU (validation), False on TPU."""
    return jax.default_backend() != "tpu"


def note_dispatch(name: str, interpret: bool, **info) -> None:
    """Report a kernel dispatch decision (compiled pallas vs
    interpret/ref fallback) to the obs bus. No-op unless a run has a
    StageTracer installed (repro.obs.trace), so kernels can call this
    unconditionally."""
    from repro.obs.trace import note_kernel
    note_kernel(name, backend=jax.default_backend(), interpret=interpret,
                **info)
