"""Kernel runtime switches."""
import jax


def interpret_default() -> bool:
    """Pallas interpret mode: True on CPU (validation), False on TPU."""
    return jax.default_backend() != "tpu"
