from repro.kernels.wire_agg.ops import wire_aggregate
from repro.kernels.wire_agg.ref import wire_agg_ref
from repro.kernels.wire_agg.wire_agg import AGGREGATORS, wire_agg_2d

__all__ = ["AGGREGATORS", "wire_agg_2d", "wire_agg_ref", "wire_aggregate"]
