"""Public wrapper for the fused dequant + masked-aggregate kernel.

`wire_aggregate` takes one leaf's stacked wire payloads (C workers) and
returns the aggregated dense delta of the original leaf shape — the
Aggregate half of the packed wire route (`channel.receive_packed`),
which never materializes the C dense reconstructions the legacy route
decodes first.

Dispatch mirrors quant_pack/ops.py: compiled pallas on TPU, the
bit-identical ref on CPU, reported via `runtime.note_dispatch`."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import runtime
from repro.kernels.wire_agg.ref import wire_agg_ref
from repro.kernels.wire_agg.wire_agg import AGGREGATORS, wire_agg_2d


def wire_aggregate(packed: jax.Array, scales: jax.Array, mask: jax.Array,
                   *, shape: tuple[int, ...], bits: int = 8,
                   aggregator: str = "mean", trim_ratio: float = 0.1,
                   weights: jax.Array | None = None,
                   interpret: bool | None = None) -> jax.Array:
    """Aggregate C packed payloads of one leaf into a dense f32 delta.

    packed: (C, rows, 128) int8 / (C, rows/2, 128) uint8 (stacked
    quant_pack wire format); scales: (C, nb) f32; mask: (C,) delivery
    mask; weights: optional (C,) per-worker weights (None = 1s; mean
    weights the sum and the denominator, robust aggregators scale the
    sorted values). Returns the (*shape,) f32 aggregate —
    `channel.receive`'s `agg` term, before the += into the global
    params. interpret=None dispatches by backend."""
    assert aggregator in AGGREGATORS, aggregator
    if interpret is None:
        interpret = runtime.interpret_default()
    C = packed.shape[0]
    runtime.note_dispatch("wire_agg", interpret, bits=bits,
                          aggregator=aggregator, workers=C)
    mask2 = mask.astype(jnp.float32).reshape(C, 1)
    w2 = (jnp.ones((C, 1), jnp.float32) if weights is None
          else weights.astype(jnp.float32).reshape(C, 1))
    if interpret:
        x2 = wire_agg_ref(packed, scales, mask2, w2, bits=bits,
                          aggregator=aggregator, trim_ratio=trim_ratio)
    else:
        x2 = wire_agg_2d(packed, scales, mask2, w2, bits=bits,
                         aggregator=aggregator, trim_ratio=trim_ratio,
                         interpret=False)
    n = 1
    for s in shape:
        n *= s
    return x2.reshape(-1)[:n].reshape(shape)
