"""Public wrapper for the fused dequant + masked-aggregate kernel.

`wire_aggregate` takes one leaf's stacked wire payloads (C workers) and
returns the aggregated dense delta of the original leaf shape — the
Aggregate half of the packed wire route (`channel.receive_packed`),
which never materializes the C dense reconstructions the legacy route
decodes first.

Dispatch mirrors quant_pack/ops.py: compiled pallas on TPU, the
bit-identical ref on CPU, reported via `runtime.note_dispatch`.

Fleets past the kernel's VMEM budget (the dequantized block is a
(C, BLOCK_ROWS, 128) f32 VMEM value, so C <~ 64 fits v5e at the default
block) take a two-stage tree for the mean: each contiguous chunk of
<= `worker_cap` workers produces a masked weighted partial SUM through
the SAME dispatch route (kernel or ref), the partials add in chunk
order, and ONE divide by the fleet-wide delivered weight finishes Eq. 7.
The chunking decision depends only on C, and both routes chunk
identically, so kernel-vs-ref stays bit-identical at every C; C <=
worker_cap keeps the legacy single-stage call (all existing pins
unchanged). Robust aggregators don't tree (order statistics don't
decompose) — their C <~ 32 sorting-network bound stands.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import runtime
from repro.kernels.wire_agg.ref import wire_agg_ref
from repro.kernels.wire_agg.wire_agg import AGGREGATORS, wire_agg_2d

# max workers per single-stage mean call: C * 128 KiB of dequantized
# f32 block must fit VMEM (wire_agg.py header) — past this the mean
# takes the two-stage tree
MEAN_WORKER_CAP = 64


def wire_aggregate(packed: jax.Array, scales: jax.Array, mask: jax.Array,
                   *, shape: tuple[int, ...], bits: int = 8,
                   aggregator: str = "mean", trim_ratio: float = 0.1,
                   weights: jax.Array | None = None,
                   interpret: bool | None = None,
                   worker_cap: int = MEAN_WORKER_CAP) -> jax.Array:
    """Aggregate C packed payloads of one leaf into a dense f32 delta.

    packed: (C, rows, 128) int8 / (C, rows/2, 128) uint8 (stacked
    quant_pack wire format); scales: (C, nb) f32; mask: (C,) delivery
    mask; weights: optional (C,) per-worker weights (None = 1s; mean
    weights the sum and the denominator, robust aggregators scale the
    sorted values). Returns the (*shape,) f32 aggregate —
    `channel.receive`'s `agg` term, before the += into the global
    params. interpret=None dispatches by backend. `worker_cap` bounds
    the per-call worker axis for the mean (two-stage tree past it)."""
    assert aggregator in AGGREGATORS, aggregator
    if interpret is None:
        interpret = runtime.interpret_default()
    C = packed.shape[0]
    mask2 = mask.astype(jnp.float32).reshape(C, 1)
    w2 = (jnp.ones((C, 1), jnp.float32) if weights is None
          else weights.astype(jnp.float32).reshape(C, 1))
    chunked = aggregator == "mean" and C > worker_cap
    runtime.note_dispatch(
        "wire_agg", interpret, bits=bits, aggregator=aggregator, workers=C,
        **({"chunks": -(-C // worker_cap)} if chunked else {}))
    if chunked:
        route = (wire_agg_ref if interpret
                 else functools.partial(wire_agg_2d, interpret=False))
        parts = [route(packed[g0:g0 + worker_cap],
                       scales[g0:g0 + worker_cap],
                       mask2[g0:g0 + worker_cap], w2[g0:g0 + worker_cap],
                       bits=bits, aggregator="sum", trim_ratio=trim_ratio)
                 for g0 in range(0, C, worker_cap)]
        s = functools.reduce(jnp.add, parts)    # fixed chunk order
        x2 = s / jnp.maximum((mask2 * w2).sum(), 1.0)
    elif interpret:
        x2 = wire_agg_ref(packed, scales, mask2, w2, bits=bits,
                          aggregator=aggregator, trim_ratio=trim_ratio)
    else:
        x2 = wire_agg_2d(packed, scales, mask2, w2, bits=bits,
                         aggregator=aggregator, trim_ratio=trim_ratio,
                         interpret=False)
    n = 1
    for s in shape:
        n *= s
    return x2.reshape(-1)[:n].reshape(shape)
