"""Pure-jnp oracle for the fused dequant + masked-aggregate kernel.

Also the CPU fallback for `repro/comm/channel.receive_packed`: it
replays `channel.receive` / `channel._robust_receive` operation-for-
operation on the stacked wire layout — same dequant multiply, same
masked sum / jnp.sort + dynamic order-statistic picks — so the packed
route is bit-identical to the legacy dense route on CPU (asserted in
tests/test_wire_kernels.py; the elementwise sums/sorts are layout-
invariant between the (C, *leaf) and padded (C, rows, 128) views).

The kernel's transposition-network sort and iota order-stat picks are
value-equal to this oracle (only ±0.0 tie placement can differ).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.quant_pack.quant_pack import (BLOCK_ROWS,
                                                 _unpack_nibbles)
from repro.kernels.wire_agg.wire_agg import _TREE_MODES


def wire_agg_ref(packed: jax.Array, scales: jax.Array, mask: jax.Array,
                 weights: jax.Array, *, bits: int = 8,
                 aggregator: str = "mean", trim_ratio: float = 0.1,
                 block_rows: int = BLOCK_ROWS) -> jax.Array:
    """Same contract as wire_agg_2d: stacked payloads (C, ...) ->
    (rows, 128) f32 aggregate delta."""
    C = packed.shape[0]
    lanes = packed.shape[2]
    assert aggregator in _TREE_MODES, aggregator
    if bits == 8:
        rows = packed.shape[1]
        q = packed.astype(jnp.float32)
    else:
        rows = packed.shape[1] * 2
        half = block_rows // 2
        q = _unpack_nibbles(packed.reshape(C, -1, half, lanes)
                            ).reshape(C, rows, lanes)
    nb = rows // block_rows
    assert scales.shape == (C, nb), (scales.shape, C, nb)
    assert mask.shape == weights.shape == (C, 1), (mask.shape,
                                                   weights.shape)
    qb = q.reshape(C, nb, block_rows, lanes)
    d = (qb * scales[:, :, None, None]).reshape(C, rows, lanes)

    if aggregator in ("mean", "sum"):
        mw = mask * weights                            # (C, 1)
        s = (mw[:, :, None] * d).sum(axis=0)
        if aggregator == "sum":     # tree partial: divide deferred
            return s
        return s / jnp.maximum(mw.sum(), 1.0)

    # robust path: verbatim channel._robust_receive math on the stacked
    # layout (jnp.sort + dynamic_index_in_dim, NOT the kernel's network,
    # so the CPU route stays bit-identical to the legacy receive)
    k = mask.sum().astype(jnp.int32)
    dw = d * weights[:, :, None]
    m3 = mask[:, :, None]
    svals = jnp.sort(jnp.where(m3 > 0, dw, jnp.inf), axis=0)
    if aggregator == "median":
        lo = jnp.maximum(k - 1, 0) // 2
        hi = jnp.maximum(k - 1, 0) - lo
        agg = 0.5 * (jax.lax.dynamic_index_in_dim(svals, lo, 0, False)
                     + jax.lax.dynamic_index_in_dim(svals, hi, 0, False))
    else:  # trimmed_mean
        t = (trim_ratio * k.astype(jnp.float32)).astype(jnp.int32)
        t = jnp.minimum(t, jnp.maximum(k - 1, 0) // 2)
        idx = jnp.arange(C).reshape(C, 1, 1)
        keep = (idx >= t) & (idx < k - t)
        cnt = jnp.maximum((k - 2 * t).astype(jnp.float32), 1.0)
        agg = jnp.where(keep, svals, 0.0).sum(axis=0) / cnt
    return jnp.where(k > 0, agg, 0.0)
