"""Fused dequant + masked-aggregate kernel (PS-side Eq. 7 decode).

The parameter server receives C packed b-bit payloads (one per worker)
plus per-block scales, a delivery mask, and per-worker weights. The
legacy path dequantizes every payload to a dense f32 reconstruction and
then aggregates — C extra (rows, 128) f32 HBM round-trips per leaf. One
grid step here reads the C packed tiles for one (BLOCK_ROWS, 128) block
straight into VMEM, dequantizes, and folds the masked aggregate (mean /
coordinate-wise median / trimmed mean — the exact `channel.receive`
math) into a single f32 output tile: reads C*b/8 bytes per element,
writes 4.

Layouts: packed is the stacked quant_pack wire format (C, rows, 128)
int8 or (C, rows/2, 128) uint8; scales (C, nb) f32; mask/weights (C, 1)
f32. The dequantized block is a (C, BLOCK_ROWS, 128) f32 VMEM value —
128 KiB per worker — so C <~ 64 fits v5e VMEM at the default block
(int4 cannot shrink the block: nibble pairing spans the 256-row quant
block). Robust aggregators additionally unroll an odd-even
transposition sorting network over the worker axis (lax.sort has no
Mosaic lowering; jnp.minimum/maximum do), so prefer C <~ 32 there.

Aggregate semantics (bit-matching comm/channel.receive at weights=1):
mean divides the (mask*weight)-weighted sum by max(sum(mask*weight),1);
median/trimmed sort the weighted values with non-delivered workers at
+inf and pick order statistics from the traced survivor count k =
mask.sum(). All-lost rounds aggregate to 0 (w_t unchanged). Order
statistics are picked by an iota mask-sum instead of dynamic indexing
(Mosaic-safe), which is value-exact: the sum adds one selected row to
zeros.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.quant_pack.quant_pack import (BLOCK_ROWS,
                                                 _unpack_nibbles)

_LANES = 128

AGGREGATORS = ("mean", "median", "trimmed_mean")

# internal two-stage mode (ops.wire_aggregate tree route): the masked
# weighted partial SUM of one worker chunk, no divide — chunk partials
# add associatively, the caller divides once by the fleet-wide weight
_TREE_MODES = AGGREGATORS + ("sum",)


def _dequant_stack(packed: jax.Array, scales: jax.Array,
                   bits: int) -> jax.Array:
    """(C, B[/2], 128) packed + (C, 1) scales -> (C, B, 128) f32.
    Identical per-element math to ref.dequant_unpack_ref (q * scale on
    the same operands), so decoded values are bit-equal to the legacy
    per-worker decode."""
    q = packed.astype(jnp.float32) if bits == 8 else _unpack_nibbles(packed)
    return q * scales[:, :, None]


def _sort_workers(vals: jax.Array) -> jax.Array:
    """Ascending sort along axis 0 (static C): odd-even transposition
    network of fully unrolled jnp.minimum/maximum compare-exchanges.
    Value-equal to jnp.sort(axis=0) — ties among equal floats are
    interchangeable (only ±0.0 ordering can differ, which no consumer
    distinguishes)."""
    rows = [vals[i] for i in range(vals.shape[0])]
    C = len(rows)
    for phase in range(C):
        for i in range(phase % 2, C - 1, 2):
            lo = jnp.minimum(rows[i], rows[i + 1])
            hi = jnp.maximum(rows[i], rows[i + 1])
            rows[i], rows[i + 1] = lo, hi
    return jnp.stack(rows, axis=0)


def _aggregate_block(d: jax.Array, mask: jax.Array, weights: jax.Array,
                     aggregator: str, trim_ratio: float,
                     sort_fn=_sort_workers) -> jax.Array:
    """Shared Eq.-7 block math: d (C, B, 128) f32 dequantized deltas,
    mask/weights (C, 1) f32 -> (B, 128) f32 aggregate. Mirrors
    channel.receive / channel._robust_receive operation-for-operation so
    outputs are bit-identical at weights=1 (the engine route)."""
    if aggregator in ("mean", "sum"):
        mw = mask * weights
        s = (mw[:, :, None] * d).sum(axis=0)
        if aggregator == "sum":     # tree partial: divide deferred
            return s
        return s / jnp.maximum(mw.sum(), 1.0)

    k = mask.sum().astype(jnp.int32)
    dw = d * weights[:, :, None]
    svals = sort_fn(jnp.where(mask[:, :, None] > 0, dw, jnp.inf))
    cidx = jax.lax.broadcasted_iota(jnp.int32, svals.shape, 0)

    def pick(j):  # order statistic j: exact (one row summed with zeros)
        return jnp.where(cidx == j, svals, 0.0).sum(axis=0)

    if aggregator == "median":
        lo = jnp.maximum(k - 1, 0) // 2
        hi = jnp.maximum(k - 1, 0) - lo
        agg = 0.5 * (pick(lo) + pick(hi))
    else:  # trimmed_mean: cut t of the k survivors from each end
        t = (trim_ratio * k.astype(jnp.float32)).astype(jnp.int32)
        t = jnp.minimum(t, jnp.maximum(k - 1, 0) // 2)
        keep = (cidx >= t) & (cidx < k - t)
        cnt = jnp.maximum((k - 2 * t).astype(jnp.float32), 1.0)
        agg = jnp.where(keep, svals, 0.0).sum(axis=0) / cnt
    return jnp.where(k > 0, agg, 0.0)    # all-lost round: w_t unchanged


def _make_agg_kernel(bits: int, aggregator: str, trim_ratio: float):
    def kernel(mask_ref, w_ref, scales_ref, packed_ref, out_ref):
        d = _dequant_stack(packed_ref[...], scales_ref[...], bits)
        out_ref[...] = _aggregate_block(d, mask_ref[...], w_ref[...],
                                        aggregator, trim_ratio)

    return kernel


@functools.partial(jax.jit, static_argnames=("bits", "aggregator",
                                             "trim_ratio", "interpret",
                                             "block_rows"))
def wire_agg_2d(packed: jax.Array, scales: jax.Array, mask: jax.Array,
                weights: jax.Array, *, bits: int = 8,
                aggregator: str = "mean", trim_ratio: float = 0.1,
                interpret: bool = True,
                block_rows: int = BLOCK_ROWS) -> jax.Array:
    """Core pallas_call on stacked wire payloads.

    packed: (C, rows, 128) int8 or (C, rows/2, 128) uint8;
    scales: (C, rows/block_rows) f32; mask, weights: (C, 1) f32.
    Returns the (rows, 128) f32 aggregate delta.
    """
    C = packed.shape[0]
    lanes = packed.shape[2]
    rows = packed.shape[1] * (2 if bits == 4 else 1)
    assert lanes == _LANES and rows % block_rows == 0, packed.shape
    assert bits in (8, 4), bits
    assert aggregator in _TREE_MODES, aggregator
    nb = rows // block_rows
    assert scales.shape == (C, nb), (scales.shape, C, nb)
    assert mask.shape == weights.shape == (C, 1), (mask.shape,
                                                   weights.shape)
    pb = block_rows // (2 if bits == 4 else 1)
    return pl.pallas_call(
        _make_agg_kernel(bits, aggregator, trim_ratio),
        grid=(nb,),
        in_specs=[pl.BlockSpec((C, 1), lambda i: (0, 0)),      # mask
                  pl.BlockSpec((C, 1), lambda i: (0, 0)),      # weights
                  pl.BlockSpec((C, 1), lambda i: (0, i)),      # scales
                  pl.BlockSpec((C, pb, lanes), lambda i: (0, i, 0))],
        out_specs=pl.BlockSpec((block_rows, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, lanes), jnp.float32),
        interpret=interpret,
    )(mask, weights, scales, packed)
