import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh)
combination on placeholder devices, record memory/cost/collective
analysis as JSON artifacts (artifacts/dryrun/<arch>__<shape>__<mesh>.json).

The XLA_FLAGS line above MUST run before any other import (jax locks the
device count at first init) — which is why it is the first statement of
this module and why this flag is never set globally (smoke tests and
benchmarks see 1 device).

Usage:
  python -m repro.launch.dryrun --arch smollm-360m --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--skip-done]
  python -m repro.launch.dryrun --arch smollm-360m --shape train_4k \
      --scenario rayleigh-uplink   # CommConfig from the registry
"""
import argparse
import gzip
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs.base import INPUT_SHAPES, get_arch, list_archs
from repro.launch import hlo_analysis, hlo_costmodel
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def model_flops_per_device(cfg, shape, n_dev: int) -> float:
    """Analytic MODEL_FLOPS (6*N_active*D train / 2*N_active*D fwd) for
    the tokens this step processes, per device."""
    tok = shape.global_batch * (shape.seq_len
                                if shape.kind != "decode" else 1)
    mult = 3 if shape.kind == "train" else 1  # fwd+bwd vs fwd
    if shape.kind == "train":
        tok += 16 * 4 * shape.seq_len  # W * EVAL_BATCH scoring fwd (approx)
    return 2 * cfg.active_param_count() * tok * mult / n_dev


def analyze_hlo(hlo: str, cfg, shape, n_dev: int) -> dict:
    """While-multiplicity-aware roofline record from the HLO text
    (hlo_costmodel corrects cost_analysis()'s scan-body undercount)."""
    cm = hlo_costmodel.analyze(hlo)
    mf = model_flops_per_device(cfg, shape, n_dev)
    return {
        "flops_per_device": cm["flops"],
        "hbm_bytes_per_device": cm["hbm_bytes"],
        "collectives": cm["collectives"],
        "max_while_trip": cm["max_while_trip"],
        "roofline": hlo_analysis.roofline(
            cm["flops"], cm["hbm_bytes"],
            cm["collectives"]["total_bytes"], mf, fma_counted=False),
    }


def pair_is_applicable(arch_name: str, shape_name: str) -> tuple[bool, str]:
    cfg = get_arch(arch_name)
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention arch: long_500k skipped per brief "
                       "(DESIGN.md §4)")
    return True, ""


def run_one(arch_name: str, shape_name: str, mesh_kind: str,
            algorithm: str = "mdsl", save_hlo: bool = True,
            tag: str = "", comm=None) -> dict:
    """`comm` (a repro.comm.CommConfig, default wire when None) threads
    compression/robust-aggregation/downlink configs into the lowered
    step, so comm scenarios cost out on the 512-device model."""
    cfg = get_arch(arch_name)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec = {"arch": arch_name, "shape": shape_name, "mesh": mesh_kind,
           "algorithm": algorithm, "devices": int(
               len(jax.devices())), "ok": False, "tag": tag}
    if comm is not None:
        rec["comm"] = comm._asdict()
    t0 = time.time()
    try:
        with jax.sharding.use_mesh(mesh) if hasattr(jax.sharding, "use_mesh") else mesh:
            built = build_step(cfg, shape, mesh, algorithm=algorithm,
                               comm=comm)
            lowered = built.fn.lower(*built.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            # older jax returns one dict per device/computation
            if isinstance(cost, (list, tuple)):
                cost = cost[0] if cost else {}
            hlo = compiled.as_text()
            n_dev = len(jax.devices())

            rec.update(
                ok=True,
                lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
                memory={
                    "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                    "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                    "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                    "generated_code_bytes": getattr(
                        mem, "generated_code_size_in_bytes", 0),
                },
                # raw XLA numbers (while/scan bodies counted ONCE — see
                # hlo_costmodel; kept for reference only)
                xla_cost={
                    "flops": float(cost.get("flops", 0.0)) if cost else 0.0,
                    "bytes_accessed": float(cost.get("bytes accessed", 0.0))
                    if cost else 0.0,
                },
                **analyze_hlo(hlo, built.cfg, shape, n_dev),
                meta={k: (list(v) if isinstance(v, tuple) else v)
                      for k, v in built.meta.items()},
            )
            if save_hlo:
                ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)
                hpath = ARTIFACT_DIR / f"{arch_name}__{shape_name}__{mesh_kind}{tag}.hlo.gz"
                with gzip.open(hpath, "wt") as f:
                    f.write(hlo)
                rec["hlo_path"] = str(hpath)
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-3000:]
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def artifact_path(arch: str, shape: str, mesh_kind: str, tag: str = "") -> Path:
    return ARTIFACT_DIR / f"{arch}__{shape}__{mesh_kind}{tag}.json"


def reanalyze_all(tag: str = "") -> None:
    """Recompute the roofline record of every artifact from its saved
    .hlo.gz (no recompilation) — used after cost-model improvements."""
    n_dev_by_mesh = {"single": 256, "multi": 512}
    for jpath in sorted(ARTIFACT_DIR.glob(f"*{tag}.json")):
        rec = json.loads(jpath.read_text())
        if not rec.get("ok"):
            continue
        hpath = Path(str(jpath)[: -len(".json")] + ".hlo.gz")
        if not hpath.exists():
            print(f"no HLO for {jpath.name}, skipping")
            continue
        with gzip.open(hpath, "rt") as f:
            hlo = f.read()
        cfg = get_arch(rec["arch"])
        shape = INPUT_SHAPES[rec["shape"]]
        rec.update(analyze_hlo(hlo, cfg, shape,
                               n_dev_by_mesh[rec["mesh"]]))
        jpath.write_text(json.dumps(rec, indent=1))
        print(f"reanalyzed {jpath.name}: "
              f"dominant={rec['roofline']['dominant']} "
              f"useful={rec['roofline']['useful_flops_ratio']:.2f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--algorithm", default="mdsl")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--tag", default="", help="artifact suffix for perf variants")
    ap.add_argument("--scenario", default=None,
                    help="resolve the CommConfig from this registry "
                         "scenario (one flag surface for comm pricing — "
                         "fading/outage/tier scenarios included)")
    ap.add_argument("--reanalyze", action="store_true",
                    help="recompute rooflines from saved HLO (no compile)")
    args = ap.parse_args()

    if args.reanalyze:
        reanalyze_all(args.tag)
        return

    comm = None
    if args.scenario:
        from repro.experiments.registry import get_scenario
        comm = get_scenario(args.scenario).comm
        if not args.tag:
            args.tag = "__" + args.scenario.replace("/", "-")

    archs = ([a for a in list_archs()] if args.all or not args.arch
             else [args.arch])
    shapes = (list(INPUT_SHAPES) if args.all or not args.shape
              else [args.shape])
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                ok, why = pair_is_applicable(arch, shape)
                path = artifact_path(arch, shape, mesh_kind, args.tag)
                if not ok:
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "skipped": True, "reason": why}
                    path.write_text(json.dumps(rec, indent=1))
                    print(f"SKIP {arch} {shape} {mesh_kind}: {why}")
                    continue
                if args.skip_done and path.exists():
                    prev = json.loads(path.read_text())
                    if prev.get("ok") or prev.get("skipped"):
                        print(f"DONE {arch} {shape} {mesh_kind} (cached)")
                        continue
                print(f"RUN  {arch} {shape} {mesh_kind} ...", flush=True)
                rec = run_one(arch, shape, mesh_kind, algorithm=args.algorithm,
                              tag=args.tag, comm=comm)
                path.write_text(json.dumps(rec, indent=1))
                status = "ok" if rec.get("ok") else f"FAIL {rec.get('error')}"
                print(f"     -> {status} ({rec['total_s']}s)", flush=True)


if __name__ == "__main__":
    main()
