"""HLO post-processing for the dry-run: collective-bytes accounting and
roofline terms.

`collective_bytes(hlo_text)` parses the post-SPMD-partitioning HLO of the
*per-device* program, resolves each collective op's operand shapes through
a first-pass symbol table, and sums operand bytes per collective kind.
`roofline(...)` combines them with cost_analysis() FLOPs/bytes into the
three-term model of the brief (per-device program semantics: every term
is seconds-per-step-per-chip; chips act in parallel, so no further /chips).
"""
from __future__ import annotations

import re
from typing import Any

from repro.launch import mesh as hw

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, Any]:
    """Sum operand bytes of every collective in the per-device program."""
    # pass 1: symbol table  name -> result type string
    symtab: dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            name, rhs = m.groups()
            tm = re.match(r"^\(?([\w\[\],\s\{\}\/#]*?)\)?\s+[\w\-]+\(", rhs)
            # result type = text before the op name; simpler: first shapes
            # up to the op keyword
            symtab[name] = rhs

    totals = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        opm = re.search(r"\b(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(", rhs)
        if not opm:
            continue
        kind = opm.group(1)
        if "-done(" in rhs:
            continue  # counted at -start
        # operand names inside the call parens
        call = rhs[opm.end():]
        operand_names = re.findall(r"%?([\w\.\-]+)", call.split(")")[0])
        op_bytes = 0
        for on in operand_names:
            if on in symtab:
                op_bytes += _shape_bytes(symtab[on].split(" ")[0]
                                         if "[" in symtab[on].split(" ")[0]
                                         else symtab[on])
        if op_bytes == 0:
            # fall back to the result type on the def line itself
            op_bytes = _shape_bytes(rhs.split(" ", 1)[0])
        totals[kind] += op_bytes
        counts[kind] += 1
    totals_all = sum(totals.values())
    return {"by_kind_bytes": totals, "by_kind_count": counts,
            "total_bytes": int(totals_all)}


def roofline(flops: float, hbm_bytes: float, coll_bytes: float,
             model_flops_per_device: float,
             fma_counted: bool = True) -> dict[str, Any]:
    """Three-term roofline (seconds, per-device program).

    With `fma_counted=True` (XLA cost_analysis convention: one fused
    multiply-add = ONE flop) the compute term doubles the count; the
    while-aware HLO cost model (`hlo_costmodel.analyze`) already counts
    2*N*M*K true flops, so it passes `fma_counted=False`.
    `useful_flops_ratio` = MODEL_FLOPS / true_FLOPs: 1.0 means every
    compiled flop is a model flop; < 1 flags remat/redundancy waste;
    > 1 flags compute the analytic 6ND model misses (attention scores,
    recurrent gates).
    """
    eff_flops = 2.0 * flops if fma_counted else float(flops)
    t_compute = eff_flops / hw.PEAK_FLOPS_BF16
    t_memory = hbm_bytes / hw.HBM_BW
    t_coll = coll_bytes / hw.ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    useful = (model_flops_per_device / eff_flops) if flops else 0.0
    return {**terms, "dominant": dominant,
            "model_flops_per_device": model_flops_per_device,
            "useful_flops_ratio": useful,
            "bound_step_s": max(terms.values())}
