"""While-multiplicity-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` visits each computation ONCE: the body
of a ``while`` loop (every ``jax.lax.scan``, i.e. our scan-over-layers
stack) is counted a single time regardless of trip count, so FLOPs,
bytes and collective counts are undercounted by ~n_layers for stacked
models (verified empirically: an 8-trip scan reports 1/8 the flops of the
unrolled loop).

This module re-derives the roofline inputs from the post-optimization
HLO text itself:

  * parses every computation into a symbol table (instruction -> shape),
  * counts dot FLOPs exactly (2 * prod(out_dims) * prod(contracting)),
  * extracts each ``while`` loop's trip count from its condition
    computation (the ``compare(iv, constant(N)), direction=LT/LE/GT/GE``
    pattern, with a max-int-constant fallback),
  * propagates multiplicities through the call graph
    (entry -> while bodies x trip, fusions/calls x 1),
  * estimates HBM traffic as the operand+output bytes of every top-level
    materializing instruction (fusion, dot, conv, collectives, copy,
    sort, scatter...) — post-fusion buffers, the standard approximation,
  * sums collective payload bytes per kind with multiplicity.

It is intentionally independent of jax: input is the HLO string from
``compiled.as_text()`` (or the dry-run's saved ``.hlo.gz``).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e3m4": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops whose operands/results move through HBM in the optimized program
_MATERIALIZING = ("fusion", "dot", "convolution", "copy", "sort", "scatter",
                  "gather", "dynamic-slice", "dynamic-update-slice", "rng",
                  "reduce", "transpose", "broadcast", "iota", "pad",
                  "concatenate", "slice", "reshape", "reverse",
                  "select-and-scatter", "cholesky", "triangular-solve",
                  ) + COLLECTIVES

_COMP_HEADER = re.compile(
    r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\((.*)\)\s+->\s+(.+)\s+\{\s*$")
# the result type may be a tuple containing `/*index=N*/` comments
_INSTR = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w\.\-]+)\s+=\s+(\(?[\w\[\],\s\{\}/\*=]*?\)?)\s+"
    r"([\w\-]+)\((.*)$")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
def _split_params(params_str: str) -> dict[str, str]:
    """Split `a: f32[2,3], b: (s32[], f32[4,5])` at bracket depth 0."""
    out: dict[str, str] = {}
    depth = 0
    start = 0
    parts = []
    for i, ch in enumerate(params_str):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == "," and depth == 0:
            parts.append(params_str[start:i])
            start = i + 1
    if params_str[start:].strip():
        parts.append(params_str[start:])
    for part in parts:
        if ":" not in part:
            continue
        name, ptype = part.split(":", 1)
        out[name.strip().lstrip("%")] = ptype.strip()
    return out


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> list[int]:
    """Dims of the FIRST array shape in a type string."""
    m = _SHAPE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instruction:
    name: str
    result_type: str
    op: str
    rest: str  # text after the opening paren of the op call


@dataclasses.dataclass
class Computation:
    name: str
    params: dict[str, str]           # param name -> type
    instructions: list[Instruction]
    is_entry: bool = False

    def symtab(self) -> dict[str, str]:
        tab = dict(self.params)
        for ins in self.instructions:
            tab[ins.name] = ins.result_type
        return tab


def parse_hlo(text: str) -> tuple[dict[str, Computation], str]:
    """-> ({name: Computation}, entry_name)."""
    comps: dict[str, Computation] = {}
    entry = ""
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HEADER.match(line)
            if m:
                is_entry, name, params_str, _ = m.groups()
                cur = Computation(name=name,
                                  params=_split_params(params_str),
                                  instructions=[], is_entry=bool(is_entry))
                if is_entry:
                    entry = name
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            iname, rtype, op, rest = m.groups()
            cur.instructions.append(Instruction(iname, rtype, op, rest))
    return comps, entry


_CALLED = re.compile(r"(?:calls=|to_apply=|body=|condition=|branch_computations=\{)"
                     r"(%?[\w\.\-]+(?:,\s*%?[\w\.\-]+)*)")
_WHILE_REFS = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_OPERANDS = re.compile(r"%([\w\.\-]+)")
_COMPARE = re.compile(r"compare\((.*?)\)[^,]*, direction=(\w+)")
_CONSTANT = re.compile(r"constant\((\d+)\)")


def _trip_count(cond: Computation) -> int:
    """Trip count from the condition computation. Returns 1 if unknown
    (conservative: no multiplication)."""
    consts = {}
    for ins in cond.instructions:
        m = _CONSTANT.search(ins.op + "(" + ins.rest)
        if m and ins.result_type.startswith(("s32[]", "s64[]", "u32[]",
                                             "u64[]")):
            consts[ins.name] = int(m.group(1))
    for ins in cond.instructions:
        if ins.op == "compare":
            direction = re.search(r"direction=(\w+)", ins.rest)
            ops = _OPERANDS.findall(ins.rest.split(")")[0])
            vals = [consts[o] for o in ops if o in consts]
            if vals and direction:
                d = direction.group(1)
                n = max(vals)
                return n + 1 if d in ("LE", "GE") else max(n, 1)
    if consts:
        return max(consts.values())
    return 1


def _dot_flops(ins: Instruction, symtab: dict[str, str]) -> int:
    """2 * prod(output) * prod(lhs contracting dims)."""
    out_dims = _shape_dims(ins.result_type)
    ops = _OPERANDS.findall(ins.rest.split(")")[0])
    if not ops:
        return 0
    lhs_type = symtab.get(ops[0], "")
    lhs_dims = _shape_dims(lhs_type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    contract = 1
    if m and lhs_dims:
        for d in m.group(1).split(","):
            if d and int(d) < len(lhs_dims):
                contract *= lhs_dims[int(d)]
    out = 1
    for d in out_dims:
        out *= d
    return 2 * out * contract


def _instr_bytes(ins: Instruction, symtab: dict[str, str]) -> int:
    """Operand + result bytes of one materializing instruction.

    dynamic-(update-)slice alias their big operand in place: traffic is
    the slice, not the buffer (a KV-cache update writes one token's K/V,
    not the whole 32k cache)."""
    if ins.op == "dynamic-update-slice":
        ops = _OPERANDS.findall(ins.rest.split(")")[0])
        upd = _shape_bytes(symtab.get(ops[1], "")) if len(ops) > 1 else 0
        return 2 * upd
    if ins.op == "dynamic-slice":
        return 2 * _shape_bytes(ins.result_type)
    total = _shape_bytes(ins.result_type)
    for op_name in _OPERANDS.findall(ins.rest.split(")")[0]):
        if op_name in symtab:
            total += _shape_bytes(symtab[op_name])
    return total


def _fusion_root(ins: Instruction, comps: dict) -> Optional[Instruction]:
    m = re.search(r"calls=%?([\w\.\-]+)", ins.rest)
    comp = comps.get(m.group(1)) if m else None
    return comp.instructions[-1] if comp and comp.instructions else None


def _fusion_is_dus(ins: Instruction, comps: dict) -> bool:
    root = _fusion_root(ins, comps)
    return root is not None and root.op == "dynamic-update-slice"


def _dus_update_bytes(ins: Instruction, comps: dict) -> int:
    m = re.search(r"calls=%?([\w\.\-]+)", ins.rest)
    comp = comps.get(m.group(1)) if m else None
    if comp is None:
        return 0
    root = comp.instructions[-1]
    symtab = comp.symtab()
    ops = _OPERANDS.findall(root.rest.split(")")[0])
    return _shape_bytes(symtab.get(ops[1], "")) if len(ops) > 1 else 0


def _collective_payload(ins: Instruction, symtab: dict[str, str]) -> int:
    """Payload bytes of a collective = operand bytes (result for AG)."""
    op_bytes = 0
    for op_name in _OPERANDS.findall(ins.rest.split(")")[0]):
        if op_name in symtab:
            op_bytes += _shape_bytes(symtab[op_name])
    if op_bytes == 0:
        op_bytes = _shape_bytes(ins.result_type)
    return op_bytes


def analyze(text: str) -> dict:
    """Multiplicity-aware totals for the whole module."""
    comps, entry = parse_hlo(text)
    if not entry:
        raise ValueError("no ENTRY computation found")

    # computations reached via fusion `calls=` are inlined (their
    # instructions do NOT touch HBM); control-flow bodies are real.
    fusion_bodies: set[str] = set()
    for comp in comps.values():
        for ins in comp.instructions:
            if ins.op == "fusion":
                m = re.search(r"calls=%?([\w\.\-]+)", ins.rest)
                if m:
                    fusion_bodies.add(m.group(1))

    memo: dict[str, tuple[int, int, dict, dict, int]] = {}

    def walk(name: str, in_fusion: bool) -> tuple[int, int, dict, dict, int]:
        """-> (flops, hbm_bytes, coll_bytes_by_kind, coll_count_by_kind,
                max_while_trip)."""
        cache_key = name
        if cache_key in memo:
            return memo[cache_key]
        comp = comps.get(name)
        if comp is None:
            return 0, 0, {}, {}, 1
        symtab = comp.symtab()
        flops = 0
        hbm = 0
        coll_b: dict[str, int] = {}
        coll_c: dict[str, int] = {}
        max_trip = 1
        for ins in comp.instructions:
            base = ins.op.replace("-start", "").replace("-done", "")
            if ins.op == "dot":
                flops += _dot_flops(ins, symtab)
            if base in COLLECTIVES and not ins.op.endswith("-done"):
                payload = _collective_payload(ins, symtab)
                coll_b[base] = coll_b.get(base, 0) + payload
                coll_c[base] = coll_c.get(base, 0) + 1
            if not in_fusion and (ins.op in _MATERIALIZING
                                  or ins.op == "fusion"):
                if ins.op == "fusion" and _fusion_is_dus(ins, comps):
                    # in-place cache update fused around a DUS: traffic
                    # is the update slice, not the carried buffer
                    hbm += 2 * _dus_update_bytes(ins, comps)
                else:
                    hbm += _instr_bytes(ins, symtab)
            # children
            if ins.op == "while":
                m = _WHILE_REFS.search(ins.rest)
                if m:
                    cond_name, body_name = m.groups()
                    trips = _trip_count(comps[cond_name]) \
                        if cond_name in comps else 1
                    max_trip = max(max_trip, trips)
                    for child, mult in ((cond_name, trips),
                                        (body_name, trips)):
                        f, b, cb, cc, mt = walk(child, in_fusion)
                        flops += mult * f
                        hbm += mult * b
                        for k, v in cb.items():
                            coll_b[k] = coll_b.get(k, 0) + mult * v
                        for k, v in cc.items():
                            coll_c[k] = coll_c.get(k, 0) + mult * v
                        max_trip = max(max_trip, mt)
            elif ins.op == "fusion":
                m = re.search(r"calls=%?([\w\.\-]+)", ins.rest)
                if m:
                    f, b, cb, cc, mt = walk(m.group(1), True)
                    flops += f
                    # fused body: no extra HBM
                    for k, v in cb.items():
                        coll_b[k] = coll_b.get(k, 0) + v
                    for k, v in cc.items():
                        coll_c[k] = coll_c.get(k, 0) + v
            elif ins.op in ("call", "conditional", "custom-call",
                            "reduce", "map", "sort", "scatter",
                            "select-and-scatter", "reduce-window",
                            "all-reduce"):
                for m in re.finditer(
                        r"(?:to_apply|calls)=%?([\w\.\-]+)", ins.rest):
                    f, b, cb, cc, mt = walk(m.group(1), in_fusion)
                    flops += f
                    hbm += b
                    for k, v in cb.items():
                        coll_b[k] = coll_b.get(k, 0) + v
                    for k, v in cc.items():
                        coll_c[k] = coll_c.get(k, 0) + v
                bm = re.search(r"branch_computations=\{([^\}]*)\}", ins.rest)
                if bm:
                    for branch in re.findall(r"%?([\w\.\-]+)",
                                             bm.group(1)):
                        f, b, cb, cc, mt = walk(branch, in_fusion)
                        # count every branch once (upper bound)
                        flops += f
                        hbm += b
                        for k, v in cb.items():
                            coll_b[k] = coll_b.get(k, 0) + v
                        for k, v in cc.items():
                            coll_c[k] = coll_c.get(k, 0) + v
        out = (flops, hbm, coll_b, coll_c, max_trip)
        memo[cache_key] = out
        return out

    flops, hbm, coll_b, coll_c, max_trip = walk(entry, False)

    # Host-backend artifact: XLA float normalization on the CPU target
    # widens some bf16 loop accumulators to f32 even though the program
    # is bf16 at the JAX level (wrapped_convert bf16[S]->f32[S] at entry
    # scope). On the real TPU target these buffers stay bf16, so we
    # report the inflation so the memory-fit check can be corrected.
    inflation = 0
    ecomp = comps[entry]
    symtab = ecomp.symtab()
    for ins in ecomp.instructions:
        if not ins.result_type.startswith("f32["):
            continue
        if ins.op == "fusion" and "wrapped_convert" in ins.rest:
            ops = _OPERANDS.findall(ins.rest.split(")")[0])
            if ops and symtab.get(ops[0], "").startswith("bf16["):
                inflation += _shape_bytes(ins.result_type) // 2
        elif ins.op == "convert":
            ops = _OPERANDS.findall(ins.rest.split(")")[0])
            if ops and symtab.get(ops[0], "").startswith("bf16["):
                inflation += _shape_bytes(ins.result_type) // 2

    return {
        "flops": int(flops),
        "hbm_bytes": int(hbm),
        "host_f32_inflation_bytes": int(inflation),
        "collectives": {
            "by_kind_bytes": {k: int(coll_b.get(k, 0)) for k in COLLECTIVES},
            "by_kind_count": {k: int(coll_c.get(k, 0)) for k in COLLECTIVES},
            "total_bytes": int(sum(coll_b.values())),
        },
        "max_while_trip": int(max_trip),
        "num_computations": len(comps),
    }
