"""Production mesh construction (required shape, see brief).

`make_production_mesh` is a FUNCTION so importing this module never
touches jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax
import to get placeholder devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


# TPU v5e hardware constants (roofline targets; this container is CPU-only)
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link
CHIP_HBM_BYTES = 16 * 2**30    # 16 GiB
