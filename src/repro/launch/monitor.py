"""`python -m repro.launch.monitor <run.jsonl> [--follow]` — the live
run dashboard. Thin alias for repro.obs.monitor so the launch package
stays the single CLI front door."""
from repro.obs.monitor import main

if __name__ == "__main__":
    main()
