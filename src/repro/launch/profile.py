"""Attribution profiler over dry-run HLO artifacts.

Prints the multiplicity-weighted top contributors (op × computation) to
the memory / FLOP / collective roofline terms — the tool behind the
§Perf hypothesis loop (EXPERIMENTS.md): given a dominant term, this
shows *which* loop body and op class to attack.

Usage:
  python -m repro.launch.profile artifacts/dryrun/deepseek-67b__train_4k__single.hlo.gz
  python -m repro.launch.profile <artifact.hlo.gz> --term flops --top 20
"""
from __future__ import annotations

import argparse
import gzip
import re
from pathlib import Path

from repro.launch import hlo_costmodel as cm


def computation_multiplicities(comps: dict, entry: str) -> dict[str, int]:
    """while-trip-weighted execution count per computation (control-flow
    bodies only; fusion bodies inherit their caller's count)."""
    mult = {entry: 1}
    order = [entry]
    i = 0
    while i < len(order):
        name = order[i]
        i += 1
        for ins in comps[name].instructions:
            if ins.op != "while":
                continue
            m = cm._WHILE_REFS.search(ins.rest)
            if not m:
                continue
            cond, body = m.groups()
            trips = cm._trip_count(comps[cond]) if cond in comps else 1
            for ch in (cond, body):
                if ch not in comps:
                    continue
                mult[ch] = mult.get(ch, 0) + mult[name] * trips
                if ch not in order:
                    order.append(ch)
    return mult


def attribute(text: str, term: str = "memory") -> list[tuple[float, str, str]]:
    """-> [(weighted_bytes_or_flops, computation, op)], sorted desc."""
    comps, entry = cm.parse_hlo(text)
    mult = computation_multiplicities(comps, entry)
    fusion_bodies = set()
    for comp in comps.values():
        for ins in comp.instructions:
            if ins.op == "fusion":
                m = re.search(r"calls=%?([\w\.\-]+)", ins.rest)
                if m:
                    fusion_bodies.add(m.group(1))

    contrib: dict[tuple[str, str], float] = {}
    for name, comp in comps.items():
        if name not in mult:
            continue
        in_fusion = name in fusion_bodies
        symtab = comp.symtab()
        for ins in comp.instructions:
            v = 0.0
            if term == "flops":
                if ins.op == "dot":
                    v = cm._dot_flops(ins, symtab)
            elif term == "collective":
                base = ins.op.replace("-start", "").replace("-done", "")
                if base in cm.COLLECTIVES and not ins.op.endswith("-done"):
                    v = cm._collective_payload(ins, symtab)
            else:  # memory
                if in_fusion:
                    continue
                if ins.op in cm._MATERIALIZING or ins.op == "fusion":
                    if ins.op == "fusion" and cm._fusion_is_dus(ins, comps):
                        v = 2 * cm._dus_update_bytes(ins, comps)
                    else:
                        v = cm._instr_bytes(ins, symtab)
            if v:
                key = (name, ins.op)
                contrib[key] = contrib.get(key, 0.0) + v * mult[name]
    return sorted(((v, n, o) for (n, o), v in contrib.items()),
                  reverse=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("artifact", help=".hlo.gz or .hlo path")
    ap.add_argument("--term", default="memory",
                    choices=["memory", "flops", "collective"])
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()
    p = Path(args.artifact)
    text = (gzip.open(p, "rt").read() if p.suffix == ".gz"
            else p.read_text())
    rows = attribute(text, args.term)
    total = sum(v for v, _, _ in rows)
    unit = "flops" if args.term == "flops" else "bytes"
    print(f"{args.term} total: {total:.3e} {unit} "
          f"({p.name}, while-trip weighted)")
    for v, name, op in rows[: args.top]:
        print(f"  {v / total * 100:5.1f}%  {v:.3e}  {op:18s} {name[:52]}")


if __name__ == "__main__":
    main()
