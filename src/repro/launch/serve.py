"""Serving driver: batched prefill + greedy/temperature decode with a KV
cache, over any assigned architecture (reduced configs execute on CPU;
full configs are exercised via the AOT dry-run only).

The M-DSL technique is train-time; serving always runs the *global*
model. This driver is the (b)-deliverable inference example and the
harness behind examples/serve_decode.py.

Usage:
  python -m repro.launch.serve --arch smollm-360m --batch 4 \\
      --prompt-len 32 --gen-len 16
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.models.transformer import Transformer

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts"


def make_request_batch(key: jax.Array, cfg, batch: int,
                       prompt_len: int) -> dict:
    """Synthetic batched requests (precomputed frontend embeddings for
    vlm/audio per the carve-out)."""
    k1, k2 = jax.random.split(key)
    out = {"tokens": jax.random.randint(k1, (batch, prompt_len), 0,
                                        cfg.vocab_size)}
    out["labels"] = out["tokens"]  # unused at serve time; keeps batch shape
    if cfg.input_mode == "tokens+prefix":
        out["prefix"] = 0.02 * jax.random.normal(
            k2, (batch, cfg.prefix_len, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.encoder_layers:
        out["frames"] = 0.02 * jax.random.normal(
            k2, (batch, cfg.encoder_memory_len, cfg.d_model),
            jnp.dtype(cfg.dtype))
    return out


def serve(arch: str, batch: int = 4, prompt_len: int = 32, gen_len: int = 16,
          reduced: bool = True, temperature: float = 0.0, seed: int = 0,
          params=None, verbose: bool = True) -> dict:
    cfg = get_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    model = Transformer(cfg)
    key = jax.random.PRNGKey(seed)
    k_init, k_req, k_samp = jax.random.split(key, 3)
    if params is None:
        params = model.init(k_init)

    cache_len = prompt_len + gen_len + (
        cfg.prefix_len if cfg.input_mode == "tokens+prefix" else 0)
    req = make_request_batch(k_req, cfg, batch, prompt_len)

    @jax.jit
    def prefill_fn(params, req):
        memory = None
        if cfg.cross_attention:
            memory = model.encode(params, req["frames"])
        cache = model.init_cache(batch, cache_len, memory=memory,
                                 params=params)
        return model.prefill(params, req, cache)

    @jax.jit
    def decode_fn(params, tokens, cache):
        return model.decode_step(params, tokens, cache)

    def sample(logits, k):
        if temperature <= 0.0:
            return jnp.argmax(logits[:, -1], axis=-1)[:, None]
        return jax.random.categorical(
            k, logits[:, -1] / temperature, axis=-1)[:, None]

    t0 = time.time()
    logits, cache = prefill_fn(params, req)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    tokens = sample(logits, k_samp)
    generated = [tokens]
    t0 = time.time()
    for i in range(gen_len - 1):
        k_samp = jax.random.fold_in(k_samp, i)
        logits, cache = decode_fn(params, tokens, cache)
        tokens = sample(logits, k_samp)
        generated.append(tokens)
    tokens.block_until_ready()
    t_decode = time.time() - t0

    out_tokens = jnp.concatenate(generated, axis=1)
    rec = {
        "arch": arch, "reduced": reduced, "batch": batch,
        "prompt_len": prompt_len, "gen_len": gen_len,
        "prefill_s": round(t_prefill, 3), "decode_s": round(t_decode, 3),
        "prefill_tok_per_s": round(batch * prompt_len / max(t_prefill, 1e-9)),
        "decode_tok_per_s": round(
            batch * max(gen_len - 1, 1) / max(t_decode, 1e-9)),
        "output_shape": list(out_tokens.shape),
        "output_sample": out_tokens[0, :8].tolist(),
    }
    if verbose:
        print(f"[serve/{arch}] prefill {rec['prefill_tok_per_s']} tok/s, "
              f"decode {rec['decode_tok_per_s']} tok/s, "
              f"out {rec['output_shape']}", flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rec = serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
                gen_len=args.gen_len, temperature=args.temperature,
                seed=args.seed)
    out = Path(args.out or ARTIFACTS / "serve" / f"{args.arch}.json")
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rec, indent=1))
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
