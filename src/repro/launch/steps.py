"""Builds the jitted (train / prefill / decode) step for an
(architecture x input-shape x mesh) combination, with full in/out
shardings, ready for `.lower(...).compile()` (dry-run) or execution.

This is the single place where the mapping decisions live:
  * swarm layout per arch (`cfg.swarm_mode`, DESIGN.md §3),
  * sharding rules per mode,
  * input_specs() — ShapeDtypeStruct stand-ins for every model input.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.comm.budget import CommConfig
from repro.comm.phy import PhyState
from repro.comm.straggler import StragglerBuffer
from repro.configs.base import ArchConfig, InputShape
from repro.core import swarm_dist
from repro.core.swarm_dist import DistSwarmConfig, DistSwarmState
from repro.models.transformer import Transformer
from repro.sharding import rules as rules_mod
from repro.sharding.param_specs import tree_shardings
from repro.sharding.rules import ShardingRules, use_rules

Array = jax.Array
PyTree = Any

EVAL_BATCH = 4          # D_g scoring batch (selection), per worker


def _prep_cfg(cfg: ArchConfig) -> ArchConfig:
    """Mesh-run config tweaks: pad vocab to a 16-multiple (seamless)."""
    if cfg.vocab_size % 16:
        cfg = dataclasses.replace(cfg, vocab_size=cfg.padded_vocab(16))
    return cfg


def swarm_layout(cfg: ArchConfig, mesh: Mesh) -> tuple[tuple[str, ...], int]:
    """(worker_axes, num_spatial_workers) per DESIGN.md §3."""
    multi = "pod" in mesh.axis_names
    if cfg.swarm_mode == "tp":
        axes = ("pod", "data") if multi else ("data",)
    else:  # fsdp
        axes = ("pod",) if multi else ()
    W = 1
    for a in axes:
        W *= mesh.shape[a]
    return axes, W


def train_rules(cfg: ArchConfig, mesh: Mesh) -> ShardingRules:
    multi = "pod" in mesh.axis_names
    if cfg.swarm_mode == "tp":
        return rules_mod.MULTI_POD_TP if multi else rules_mod.SINGLE_POD_TP
    return (rules_mod.MULTI_POD_FSDP_TP if multi
            else rules_mod.SINGLE_POD_FSDP_TP)


def serve_rules(cfg: ArchConfig, mesh: Mesh, long_context: bool
                ) -> ShardingRules:
    multi = "pod" in mesh.axis_names
    batch_axes = ("pod", "data") if multi else ("data",)
    # KV-cache head sharding only works when kv_heads divides the model
    # axis; otherwise shard the cache SEQUENCE over "model" instead
    # (flash-decode style: GSPMD inserts the partial-softmax collectives).
    # Without this, archs with kv=8 on a 16-way model axis replicate a
    # ~47 GiB cache per device (EXPERIMENTS.md §Perf iteration 3).
    kv_shardable = cfg.num_kv_heads % mesh.shape["model"] == 0
    r = ShardingRules(
        batch=None, seq=None,
        embed=None,
        # big archs keep FSDP-sharded weights at serving too (memory),
        # small archs are pure-TP (no per-layer all-gathers)
        embed_fsdp="data" if cfg.swarm_mode == "fsdp" else None,
        heads="model", kv_heads="model", q_per_kv=None, head_dim=None,
        # activation heads follow the weights only when the cache stays
        # head-sharded; with a seq-sharded cache the act heads replicate
        act_heads="model" if kv_shardable else None,
        act_kv_heads="model" if kv_shardable else None,
        residual_seq=None,
        mlp="model", vocab="model",
        expert="data" if cfg.num_experts >= 64 else "model",
        expert_mlp="model" if cfg.num_experts >= 64 else None,
        worker=None,
        cache_batch=batch_axes,
        cache_seq=None if kv_shardable else "model",
        # shard_map EP dispatch at serving too (no vmap wrapper there)
        moe_ep=cfg.num_experts >= 64,
    )
    if long_context:
        # batch=1: context-parallel KV cache over the data axis
        r = ShardingRules(r, cache_batch=None, cache_seq="data")
        r["batch"] = None
    else:
        r["batch"] = batch_axes
    return r


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------

def _token_batch_specs(cfg: ArchConfig, batch: int, seq: int,
                       lead: tuple[int, ...] = ()) -> dict:
    """ShapeDtypeStructs of one model batch (tokens + labels + frontends)."""
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    out = {"tokens": sds(lead + (batch, seq), i32),
           "labels": sds(lead + (batch, seq), i32)}
    if cfg.input_mode == "tokens+prefix":
        out["prefix"] = sds(lead + (batch, cfg.prefix_len, cfg.d_model),
                            jnp.dtype(cfg.dtype))
    if cfg.encoder_layers:
        out["frames"] = sds(lead + (batch, cfg.encoder_memory_len,
                                    cfg.d_model), jnp.dtype(cfg.dtype))
    return out


def input_specs(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
                ) -> dict:
    """ShapeDtypeStruct stand-ins for every input of the lowered step
    (weak-type-correct, shardable, no device allocation)."""
    cfg = _prep_cfg(cfg)
    if shape.kind == "train":
        axes, W = swarm_layout(cfg, mesh)
        per_worker = shape.global_batch // max(W, 1)
        return {
            "batch": _token_batch_specs(cfg, per_worker, shape.seq_len,
                                        lead=(W,)),
            "eval_batch": _token_batch_specs(cfg, EVAL_BATCH, shape.seq_len),
            "key": jax.ShapeDtypeStruct((2,), jnp.uint32),
        }
    if shape.kind == "prefill":
        return {"batch": _token_batch_specs(cfg, shape.global_batch,
                                            shape.seq_len)}
    # decode: one new token against a cache of seq_len
    return {"tokens": jax.ShapeDtypeStruct((shape.global_batch, 1),
                                           jnp.int32)}


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

class BuiltStep(NamedTuple):
    fn: Any                  # jitted function
    args: tuple              # ShapeDtypeStruct args matching fn signature
    rules: ShardingRules
    cfg: ArchConfig
    meta: dict


def _shard_batch_specs(batch: dict, rules: ShardingRules, mesh: Mesh,
                       worker_axes: Optional[tuple] = None) -> dict:
    """NamedShardings for a token batch dict (optionally worker-stacked)."""
    def leaf(name, x):
        if worker_axes is not None:
            wspec = worker_axes if len(worker_axes) != 1 else worker_axes[0]
            body = (rules.get("batch"),) + (None,) * (x.ndim - 2)
            spec = P(wspec if worker_axes else None, *body)
        else:
            spec = P(rules.get("batch"), *(None,) * (x.ndim - 1))
        # drop non-divisible axes
        fixed = []
        for dim, ax in zip(x.shape, spec):
            if ax is None:
                fixed.append(None)
                continue
            axt = (ax,) if isinstance(ax, str) else tuple(ax)
            size = 1
            for a in axt:
                size *= mesh.shape[a]
            fixed.append(ax if dim % size == 0 else None)
        return NamedSharding(mesh, P(*fixed))

    return {k: leaf(k, v) for k, v in batch.items()}


def build_train_step(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
                     algorithm: str = "mdsl",
                     comm: Optional[CommConfig] = None,
                     population: int = 0) -> BuiltStep:
    """The M-DSL communication round as one jitted SPMD program. `comm`
    threads the wire config (compression / channel / aggregator /
    downlink) into the mesh round, so comm scenarios lower and cost out
    at 512-device scale exactly like the defaults. `population > 0`
    prices a P-device registry next to the step (population_specs) and
    reports its sharded footprint in the meta."""
    cfg = _prep_cfg(cfg)
    rules = train_rules(cfg, mesh)
    worker_axes, W = swarm_layout(cfg, mesh)
    model = Transformer(cfg)
    # auto microbatching: bound the per-local-step activation footprint
    # at ~8 sequences per device batch (grad accumulation over chunks)
    per_worker = shape.global_batch // max(W, 1)
    micro = cfg.train_microbatches or min(8, max(1, per_worker // 8))
    dcfg = DistSwarmConfig(worker_axes=worker_axes, num_spatial=W,
                           local_steps=1, tau=0.9, microbatches=micro,
                           comm=(comm or CommConfig()).validate())

    loss_fn = model.loss
    step = (swarm_dist.build_train_step(loss_fn, dcfg) if algorithm == "mdsl"
            else swarm_dist.fedavg_train_step(loss_fn, dcfg))

    specs = input_specs(cfg, shape, mesh)
    key = jax.random.PRNGKey(0)
    param_shapes = jax.eval_shape(model.init, key)
    state_shapes = jax.eval_shape(
        functools.partial(swarm_dist.init_state, cfg=dcfg), param_shapes)

    wspec = (tuple(worker_axes) if len(worker_axes) != 1 else worker_axes[0]
             ) if worker_axes else None
    pshard = lambda t, w: tree_shardings(
        t, rules, mesh, prefix_axes=1 if w else 0,
        prefix_spec=(wspec,) if w else None)
    scalar = NamedSharding(mesh, P())
    wvec = NamedSharding(mesh, P(wspec))
    state_shardings = DistSwarmState(
        params=pshard(state_shapes.params, True),
        velocity=pshard(state_shapes.velocity, True),
        best_params=pshard(state_shapes.best_params, True),
        best_loss=wvec,
        global_params=pshard(state_shapes.global_params, False),
        gbest_params=pshard(state_shapes.gbest_params, False),
        gbest_loss=scalar, prev_theta_mean=scalar, eta=wvec,
        round_idx=scalar,
        residual=pshard(state_shapes.residual, True),
        ps_residual=pshard(state_shapes.ps_residual, False),
        phy=PhyState(h_re=wvec, h_im=wvec, pathloss_db=wvec, snr_db=wvec,
                     age=wvec),
        # parked late deltas shard like the uplink residual (worker-
        # stacked model tree); ages are a (W,) vector like phy columns
        buffer=(StragglerBuffer(
                    delta=pshard(state_shapes.buffer.delta, True),
                    age=wvec)
                if state_shapes.buffer is not None else None))

    batch_sh = _shard_batch_specs(specs["batch"], rules, mesh,
                                  worker_axes=worker_axes)
    eval_sh = _shard_batch_specs(specs["eval_batch"],
                                 ShardingRules(rules, batch=None), mesh)
    in_sh = (state_shardings, batch_sh, eval_sh, scalar)
    info_sh = swarm_dist.RoundInfo(losses=wvec, theta=wvec, mask=wvec,
                                   global_loss=scalar, selected_count=scalar,
                                   uploaded_params=scalar, bytes_up=scalar,
                                   bytes_down=scalar, delivered=scalar,
                                   compression_ratio=scalar,
                                   airtime_s=scalar, energy_j=scalar,
                                   mean_snr_db=scalar)
    if dcfg.comm.round_deadline_s is not None:
        info_sh = info_sh._replace(late=scalar, drained=scalar,
                                   buffered=scalar, held=scalar)
    if dcfg.comm.fault_prob:
        info_sh = info_sh._replace(transmitted=scalar)

    def wrapped(state, batch, eval_batch, key):
        with use_rules(rules, mesh):
            return step(state, batch, eval_batch, key)

    # donate the swarm state: the round updates it in place, halving the
    # state footprint vs double-buffering
    fn = jax.jit(wrapped, in_shardings=in_sh,
                 out_shardings=(state_shardings, info_sh),
                 donate_argnums=(0,))
    args = (state_shapes, specs["batch"], specs["eval_batch"], specs["key"])
    meta = {"W": W, "worker_axes": worker_axes, "algorithm": algorithm}
    if population:
        _, _, pop_meta = population_specs(dcfg.comm, population, mesh,
                                          worker_axes)
        meta["population"] = population
        meta["population_table_bytes"] = pop_meta["table_bytes"]
        meta["population_bytes_per_shard"] = pop_meta["bytes_per_shard"]
    return BuiltStep(fn=fn, args=args, rules=rules, cfg=cfg, meta=meta)


def population_specs(comm: CommConfig, population: int, mesh: Mesh,
                     worker_axes: tuple[str, ...]
                     ) -> tuple[Any, Any, dict]:
    """Dry-run shapes + shardings for a P-device population table on a
    mesh (core/population.py). The table is nine (P,) scalar columns, so
    it shards 1-D over the worker axes like the cohort's phy/eta vectors
    — 36 bytes/device split W ways, never an O(P) model pytree. Returns
    (ShapeDtypeStruct tree, NamedSharding tree, meta) where meta prices
    the footprint per host."""
    from repro.core import population as pop
    specs = pop.table_specs(population)
    wspec = (tuple(worker_axes) if len(worker_axes) != 1 else worker_axes[0]
             ) if worker_axes else None
    vec = NamedSharding(mesh, P(wspec))
    shardings = jax.tree.map(lambda _: vec, specs)
    total = sum(s.size * s.dtype.itemsize for s in jax.tree.leaves(specs))
    W = 1
    for a in worker_axes:
        W *= mesh.shape[a]
    return specs, shardings, {
        "population": population, "table_bytes": total,
        "bytes_per_shard": total // max(W, 1), "worker_axes": worker_axes}


def _serve_cache_shapes(model: Transformer, cfg: ArchConfig, batch: int,
                        cache_len: int) -> PyTree:
    memory = None
    params = None
    if cfg.cross_attention:
        memory = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_memory_len, cfg.d_model),
            jnp.dtype(cfg.dtype))
        params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        return jax.eval_shape(
            lambda p, m: model.init_cache(batch, cache_len, memory=m,
                                          params=p), params, memory)
    return jax.eval_shape(lambda: model.init_cache(batch, cache_len))


def build_serve_step(cfg: ArchConfig, shape: InputShape, mesh: Mesh
                     ) -> BuiltStep:
    """prefill_32k -> prefill step; decode_32k / long_500k -> decode step
    (one token against a seq_len cache)."""
    cfg = _prep_cfg(cfg)
    long_ctx = shape.seq_len > 100_000
    rules = serve_rules(cfg, mesh, long_ctx)
    model = Transformer(cfg)
    specs = input_specs(cfg, shape, mesh)
    param_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    param_sh = tree_shardings(param_shapes, rules, mesh)

    if shape.kind == "prefill":
        cache_shapes = _serve_cache_shapes(model, cfg, shape.global_batch,
                                           shape.seq_len)
        cache_sh = tree_shardings(cache_shapes, rules, mesh, table="cache")
        batch_sh = _shard_batch_specs(specs["batch"], rules, mesh)

        def prefill(params, batch, cache):
            with use_rules(rules, mesh):
                if cfg.cross_attention:
                    memory = model.encode(params, batch["frames"])
                    cache = model.init_cache(batch["tokens"].shape[0],
                                             shape.seq_len, memory=memory,
                                             params=params)
                return model.prefill(params, batch, cache)

        fn = jax.jit(prefill, in_shardings=(param_sh, batch_sh, cache_sh),
                     out_shardings=(NamedSharding(mesh, P()), cache_sh),
                     donate_argnums=(2,))
        args = (param_shapes, specs["batch"], cache_shapes)
        return BuiltStep(fn=fn, args=args, rules=rules, cfg=cfg,
                         meta={"mode": "prefill"})

    # decode
    cache_shapes = _serve_cache_shapes(model, cfg, shape.global_batch,
                                       shape.seq_len)
    cache_sh = tree_shardings(cache_shapes, rules, mesh, table="cache")
    tok_sh = _shard_batch_specs({"tokens": specs["tokens"]}, rules,
                                mesh)["tokens"]

    def decode(params, tokens, cache):
        with use_rules(rules, mesh):
            return model.decode_step(params, tokens, cache)

    logits_sh = NamedSharding(mesh, P(rules.get("batch"), None, None))
    # donate the KV cache: the functional update aliases in place
    fn = jax.jit(decode, in_shardings=(param_sh, tok_sh, cache_sh),
                 out_shardings=(logits_sh, cache_sh),
                 donate_argnums=(2,))
    args = (param_shapes, specs["tokens"], cache_shapes)
    return BuiltStep(fn=fn, args=args, rules=rules, cfg=cfg,
                     meta={"mode": "decode", "long": long_ctx})


def build_step(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
               algorithm: str = "mdsl",
               comm: Optional[CommConfig] = None) -> BuiltStep:
    if shape.kind == "train":
        return build_train_step(cfg, shape, mesh, algorithm, comm=comm)
    return build_serve_step(cfg, shape, mesh)
