"""Training driver.

Two modes:

  paper  — the paper's experiment (§V): C=50 edge workers, 5-layer CNN or
           compact ResNet on synthetic MNIST/CIFAR-like data partitioned
           iid / non-iid-I (Dir 0.5) / non-iid-II (mixed fleet, Fig. 2),
           algorithm in {fedavg, dsl, multi_dsl, mdsl}. Writes a metrics
           JSON (accuracy curve, comm cost, selection trace) consumed by
           benchmarks/fig3_accuracy.py and comm_efficiency.py.

  mesh   — the production path: a (reduced) assigned architecture driven
           through core/swarm_dist.py's jitted SPMD round on the active
           mesh, with checkpointing. On CPU this runs the same program
           the dry-run lowers for 512 devices.

Both modes thread a repro.comm CommConfig through the engine:
--compressor/--topk-ratio/--no-error-feedback, --channel/--drop-prob/
--snr-db, --byzantine/--byzantine-mode, --aggregator/--trim-ratio
(robust Eq. 7), --downlink-compressor (quantized broadcast with PS-side
error feedback), --adaptive-bits (per-worker wire tier from the Eq.-5
rank). The config is validated at arg-parse time so bad flags fail
fast, and the metrics JSON carries per-round bytes_up/bytes_down/
delivered next to the accuracy curve.

Usage:
  python -m repro.launch.train --mode paper --algorithm mdsl --case noniid2 \\
      --dataset cifar_like --rounds 40
  python -m repro.launch.train --mode paper --algorithm mdsl --rounds 5 \\
      --compressor topk --channel erasure
  python -m repro.launch.train --mode paper --byzantine 3 \\
      --aggregator median --downlink-compressor int8
  python -m repro.launch.train --mode mesh --arch smollm-360m --steps 5
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.comm import (AGGREGATORS, BYZANTINE_MODES, CHANNELS, COMPRESSORS,
                        CommConfig, dense_bytes, downlink_config,
                        payload_bytes)
from repro.configs.base import get_arch
from repro.configs.paper_cnn import paper_cnn, paper_resnet
from repro.core import losses as losses_mod
from repro.core import mdsl, noniid
from repro.core.mdsl import MdslConfig
from repro.core.pso import PsoHyperParams
from repro.data import partition
from repro.data.synthetic import CIFAR_LIKE, MNIST_LIKE

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts"

def _noniid2_groups(C: int) -> list[tuple[int, float]]:
    """Fig. 2 fleet (20 @ 0.1, 15 @ 0.5, 10 @ 1.0, 5 @ 10.0), scaled
    proportionally to C workers (quick-mode benchmarks use C < 50)."""
    fracs = [(0.4, 0.1), (0.3, 0.5), (0.2, 1.0), (0.1, 10.0)]
    counts = [max(1, round(f * C)) for f, _ in fracs]
    counts[0] += C - sum(counts)  # absorb rounding into the largest group
    return [(c, a) for c, (_, a) in zip(counts, fracs)]


CASES = {
    "iid": lambda key, C, spec, n: partition.iid_partition(
        key, C, spec, n_local=n),
    "noniid1": lambda key, C, spec, n: partition.dirichlet_partition(
        key, C, 0.5, spec, n_local=n),
    "noniid2": lambda key, C, spec, n: partition.mixed_dirichlet_partition(
        key, _noniid2_groups(C), spec, n_local=n),
}
SPECS = {"mnist_like": MNIST_LIKE, "cifar_like": CIFAR_LIKE}


def make_case_data(case: str, dataset: str, num_workers: int, seed: int,
                   n_local: int = 512):
    spec = SPECS[dataset]
    return CASES[case](jax.random.PRNGKey(seed), num_workers, spec,
                       n_local), spec


def run_paper_experiment(algorithm: str = "mdsl", case: str = "noniid1",
                         dataset: str = "mnist_like", rounds: int = 20,
                         num_workers: int = 50, model: str = "cnn",
                         width_mult: int = 8, tau: float = 0.9,
                         local_epochs: int = 4, batch_size: int = 64,
                         lr: float = 0.01, velocity_clip: float = 0.1,
                         seed: int = 0, eta_coeffs: Optional[tuple] = None,
                         n_local: int = 512, log_every: int = 1,
                         comm: Optional[CommConfig] = None,
                         verbose: bool = True) -> dict:
    """One full training run; returns the metrics record."""
    comm = (comm or CommConfig()).validate()
    data, spec = make_case_data(case, dataset, num_workers, seed, n_local)
    img_model = (paper_cnn(spec, width_mult) if model == "cnn"
                 else paper_resnet(spec, width_mult))
    L = spec.num_classes

    loss_fn = lambda p, x, y: losses_mod.cross_entropy_loss(
        img_model.apply(p, x), y, L)
    eval_fn = lambda p, x, y: losses_mod.rmse_loss(  # Eq. 3 scoring on D_g
        img_model.apply(p, x), y, L)

    coeffs = (noniid.EtaCoefficients(*eta_coeffs) if eta_coeffs
              else (noniid.MNIST_COEFFS if dataset == "mnist_like"
                    else noniid.CIFAR10_COEFFS))
    eta = noniid.noniid_degree_from_labels(data.y, data.global_y, L, coeffs)

    cfg = MdslConfig(algorithm=algorithm, tau=tau, local_epochs=local_epochs,
                     batch_size=batch_size,
                     hp=PsoHyperParams(learning_rate=lr,
                                       velocity_clip=velocity_clip),
                     comm=comm)
    key = jax.random.PRNGKey(seed + 1)
    state = mdsl.init_state(key, img_model.init, num_workers, eta)
    n_params = mdsl.count_params(state.global_params)

    @jax.jit
    def test_accuracy(params):
        return losses_mod.accuracy(img_model.apply(params, data.test_x),
                                   data.test_y)

    record = {"algorithm": algorithm, "case": case, "dataset": dataset,
              "model": img_model.name, "rounds": rounds,
              "num_workers": num_workers, "tau": tau, "seed": seed,
              "n_params": n_params, "eta": np.asarray(eta).tolist(),
              "comm": comm._asdict(),
              "payload_bytes_per_worker": payload_bytes(
                  comm, state.global_params),
              "dense_bytes_per_worker": dense_bytes(state.global_params),
              "downlink_bytes_per_worker": payload_bytes(
                  downlink_config(comm), state.global_params),
              "acc": [], "global_loss": [], "selected": [], "delivered": [],
              "uploaded_params": [], "bytes_up": [], "bytes_down": [],
              "round_time_s": []}

    for t in range(rounds):
        key, rkey = jax.random.split(key)
        t0 = time.time()
        state, metrics = mdsl.mdsl_round(
            state, data.x, data.y, data.global_x, data.global_y, rkey,
            loss_fn=loss_fn, eval_fn=eval_fn, cfg=cfg, n_params=n_params)
        acc = float(test_accuracy(state.global_params))
        record["acc"].append(acc)
        record["global_loss"].append(float(metrics.global_loss))
        record["selected"].append(int(metrics.selected_count))
        record["delivered"].append(int(metrics.delivered_count))
        record["uploaded_params"].append(float(metrics.uploaded_params))
        # exact ints host-side: the in-jit f32 CommRecord drifts > 16 MiB
        # (adaptive tiers mix payloads per worker, so trust the in-jit
        # accounting there)
        record["bytes_up"].append(
            float(metrics.bytes_up) if comm.adaptive_bits
            else int(metrics.selected_count)
            * record["payload_bytes_per_worker"])
        record["bytes_down"].append(
            num_workers * record["downlink_bytes_per_worker"])
        record["round_time_s"].append(round(time.time() - t0, 2))
        if verbose and (t % log_every == 0 or t == rounds - 1):
            print(f"[{algorithm}/{case}/{dataset}] round {t + 1}/{rounds} "
                  f"acc={acc:.3f} loss={float(metrics.global_loss):.4f} "
                  f"selected={int(metrics.selected_count)}/{num_workers} "
                  f"up={float(metrics.bytes_up) / 2**20:.2f}MiB",
                  flush=True)
    record["final_acc"] = record["acc"][-1]
    record["best_acc"] = max(record["acc"])
    record["total_uploaded_params"] = float(sum(record["uploaded_params"]))
    record["total_bytes_up"] = float(sum(record["bytes_up"]))
    record["total_bytes_down"] = float(sum(record["bytes_down"]))
    # adaptive tiers mix payloads per worker: the fleet-mean ratio comes
    # from the in-jit accounting, matching the bytes_up column
    record["compression_ratio"] = (
        float(metrics.compression_ratio) if comm.adaptive_bits
        else record["dense_bytes_per_worker"]
        / record["payload_bytes_per_worker"])
    return record


def run_mesh_training(arch: str, steps: int = 5, reduced: bool = True,
                      seq_len: int = 128, per_worker_batch: int = 2,
                      num_spatial: int = 2, ckpt_dir: Optional[str] = None,
                      seed: int = 0, comm: Optional[CommConfig] = None,
                      verbose: bool = True) -> dict:
    """Production path on the active devices: DistSwarm round on a
    (reduced) assigned arch. On a real TPU mesh the same builder is used
    with the full config via launch/steps.py; on CPU we exercise the jitted
    round end-to-end (real allocation, so reduced=True is required)."""
    from repro.core import swarm_dist
    from repro.core.swarm_dist import DistSwarmConfig
    from repro.models.transformer import Transformer

    cfg = get_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    model = Transformer(cfg)
    dcfg = DistSwarmConfig(worker_axes=(), num_spatial=num_spatial,
                           local_steps=1, tau=0.9,
                           hp=PsoHyperParams(learning_rate=3e-3,
                                             velocity_clip=1.0),
                           comm=(comm or CommConfig()).validate())
    key = jax.random.PRNGKey(seed)
    params = model.init(key)
    state = swarm_dist.init_state(params, dcfg)
    step_fn = jax.jit(swarm_dist.build_train_step(model.loss, dcfg))

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    W, B, S = num_spatial, per_worker_batch, seq_len

    def batch_for(k, lead):
        toks = jax.random.randint(k, lead + (B, S), 0, cfg.vocab_size)
        out = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=-1)}
        if cfg.input_mode == "tokens+prefix":
            out["prefix"] = jnp.zeros(lead + (B, cfg.prefix_len, cfg.d_model),
                                      jnp.dtype(cfg.dtype))
        if cfg.encoder_layers:
            out["frames"] = jax.random.normal(
                k, lead + (B, cfg.encoder_memory_len, cfg.d_model),
                jnp.dtype(cfg.dtype))
        return out

    payload = payload_bytes(dcfg.comm, params)
    down_payload = payload_bytes(downlink_config(dcfg.comm), params)
    record = {"arch": arch, "reduced": reduced, "steps": steps,
              "comm": dcfg.comm._asdict(),
              "payload_bytes_per_worker": payload,
              "downlink_bytes_per_worker": down_payload, "global_loss": [],
              "worker_losses": [], "selected": [], "delivered": [],
              "bytes_up": [], "bytes_down": [], "step_time_s": []}
    for i in range(steps):
        key, k1, k2, k3 = jax.random.split(key, 4)
        t0 = time.time()
        state, info = step_fn(state, batch_for(k1, (W,)), batch_for(k2, ()),
                              k3)
        gl = float(info.global_loss)
        record["global_loss"].append(gl)
        record["worker_losses"].append(np.asarray(info.losses).tolist())
        record["selected"].append(float(info.mask.sum()))
        record["delivered"].append(float(info.delivered))
        # exact ints host-side (the in-jit f32 drifts above 16 MiB)
        record["bytes_up"].append(
            float(info.bytes_up) if dcfg.comm.adaptive_bits
            else int(info.mask.sum()) * payload)
        record["bytes_down"].append(W * down_payload)
        record["step_time_s"].append(round(time.time() - t0, 2))
        if verbose:
            print(f"[mesh/{arch}] step {i + 1}/{steps} global_loss={gl:.4f} "
                  f"selected={int(info.mask.sum())}/{W}", flush=True)
        if mgr is not None:
            mgr.save(i, state.global_params, metadata={"arch": arch})
    if mgr is not None:
        record["ckpt_steps"] = mgr.all_steps()
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="paper", choices=["paper", "mesh"])
    # paper mode
    ap.add_argument("--algorithm", default="mdsl",
                    choices=["fedavg", "dsl", "multi_dsl", "mdsl"])
    ap.add_argument("--case", default="noniid1", choices=list(CASES))
    ap.add_argument("--dataset", default="mnist_like", choices=list(SPECS))
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--workers", type=int, default=50)
    ap.add_argument("--model", default="cnn", choices=["cnn", "resnet"])
    ap.add_argument("--width-mult", type=int, default=8)
    ap.add_argument("--tau", type=float, default=0.9)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    # comm (both modes)
    ap.add_argument("--compressor", default="identity",
                    choices=list(COMPRESSORS))
    ap.add_argument("--topk-ratio", type=float, default=0.05)
    ap.add_argument("--no-error-feedback", action="store_true")
    ap.add_argument("--channel", default="ideal", choices=list(CHANNELS))
    ap.add_argument("--drop-prob", type=float, default=0.1)
    ap.add_argument("--snr-db", type=float, default=20.0)
    ap.add_argument("--byzantine", type=int, default=0)
    ap.add_argument("--byzantine-mode", default="sign_flip",
                    choices=list(BYZANTINE_MODES))
    ap.add_argument("--byzantine-scale", type=float, default=1.0)
    ap.add_argument("--aggregator", default="mean",
                    choices=list(AGGREGATORS))
    ap.add_argument("--trim-ratio", type=float, default=0.1)
    ap.add_argument("--downlink-compressor", default="identity",
                    choices=list(COMPRESSORS))
    ap.add_argument("--adaptive-bits", action="store_true")
    # mesh mode
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    comm = CommConfig(
        compressor=args.compressor, topk_ratio=args.topk_ratio,
        error_feedback=not args.no_error_feedback, channel=args.channel,
        drop_prob=args.drop_prob, snr_db=args.snr_db,
        byzantine=args.byzantine, byzantine_mode=args.byzantine_mode,
        byzantine_scale=args.byzantine_scale, aggregator=args.aggregator,
        trim_ratio=args.trim_ratio,
        downlink_compressor=args.downlink_compressor,
        adaptive_bits=args.adaptive_bits)
    try:
        # fail fast at the CLI, not deep inside the first jitted round
        comm.validate()
    except ValueError as e:
        ap.error(str(e))

    if args.mode == "paper":
        rec = run_paper_experiment(
            algorithm=args.algorithm, case=args.case, dataset=args.dataset,
            rounds=args.rounds, num_workers=args.workers, model=args.model,
            width_mult=args.width_mult, tau=args.tau, seed=args.seed,
            comm=comm)
        out = args.out or (ARTIFACTS / "train" /
                           f"{args.algorithm}__{args.case}__{args.dataset}"
                           f"__s{args.seed}.json")
    else:
        rec = run_mesh_training(args.arch, steps=args.steps,
                                ckpt_dir=args.ckpt_dir, seed=args.seed,
                                comm=comm)
        out = args.out or (ARTIFACTS / "train" / f"mesh__{args.arch}.json")
    out = Path(out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rec, indent=1))
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
