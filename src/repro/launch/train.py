"""Training driver — a thin CLI over `repro.experiments`.

The scenario registry is the front door:

  python -m repro.launch.train --list-scenarios
  python -m repro.launch.train --scenario paper/fig3-noniid1 \\
      --set run.rounds=2 --set data.num_workers=8
  python -m repro.launch.train --scenario mesh/smollm-smoke --steps 3

Legacy flags still work and are mapped through the same spec (so every
flag combination is expressible — and serializable — as an
`ExperimentSpec`):

  python -m repro.launch.train --mode paper --algorithm mdsl --case noniid2 \\
      --dataset cifar_like --rounds 40
  python -m repro.launch.train --mode paper --byzantine 3 \\
      --aggregator median --downlink-compressor int8
  python -m repro.launch.train --mode mesh --arch smollm-360m --steps 5

Precedence: scenario preset < explicit legacy flags < --set overrides.
The spec is validated at arg-parse time so bad flags fail fast; the
metrics JSON artifact embeds the full spec next to the metrics.

`run_paper_experiment` / `run_mesh_training` remain as deprecated shims
over `experiments.run` — golden-pinned (tests/test_experiments.py) to
emit identical metrics on the default path.
"""
from __future__ import annotations

import argparse
from typing import Optional

from repro.comm import (AGGREGATORS, BYZANTINE_MODES, CHANNELS, COMPRESSORS,
                        FADING_MODELS, TIER_RANKS, CommConfig)
from repro.experiments import (ExperimentSpec, default_out, get_scenario,
                               describe_scenarios, override, run, sweep)
from repro.experiments.runner import (ARTIFACTS, CASES, IMAGE_SPECS,
                                      _noniid2_groups, make_case_data,
                                      spec_from_mesh_kwargs,
                                      spec_from_paper_kwargs)
from repro.experiments.spec import PARTITION_CASES, PAPER_DATASETS

# legacy alias (pre-registry callers imported the case/spec tables here)
SPECS = IMAGE_SPECS

__all__ = ["ARTIFACTS", "CASES", "SPECS", "run_paper_experiment",
           "run_mesh_training", "make_case_data", "build_spec_from_args",
           "build_sweep_specs", "main", "_noniid2_groups"]


def run_paper_experiment(algorithm: str = "mdsl", case: str = "noniid1",
                         dataset: str = "mnist_like", rounds: int = 20,
                         num_workers: int = 50, model: str = "cnn",
                         width_mult: int = 8, tau: float = 0.9,
                         local_epochs: int = 4, batch_size: int = 64,
                         lr: float = 0.01, velocity_clip: float = 0.1,
                         seed: int = 0, eta_coeffs: Optional[tuple] = None,
                         n_local: int = 512, log_every: int = 1,
                         comm: Optional[CommConfig] = None,
                         verbose: bool = True) -> dict:
    """Deprecated: build an `ExperimentSpec` and call
    `repro.experiments.run` instead. Kept as a golden-pinned shim —
    identical metrics record on every legacy call path."""
    spec = spec_from_paper_kwargs(
        algorithm=algorithm, case=case, dataset=dataset, rounds=rounds,
        num_workers=num_workers, model=model, width_mult=width_mult,
        tau=tau, local_epochs=local_epochs, batch_size=batch_size, lr=lr,
        velocity_clip=velocity_clip, seed=seed, eta_coeffs=eta_coeffs,
        n_local=n_local, log_every=log_every, comm=comm)
    return run(spec, verbose=verbose).record


def run_mesh_training(arch: str, steps: int = 5, reduced: bool = True,
                      seq_len: int = 128, per_worker_batch: int = 2,
                      num_spatial: int = 2, ckpt_dir: Optional[str] = None,
                      seed: int = 0, comm: Optional[CommConfig] = None,
                      verbose: bool = True) -> dict:
    """Deprecated: build an `ExperimentSpec` and call
    `repro.experiments.run` instead (golden-pinned shim)."""
    spec = spec_from_mesh_kwargs(
        arch=arch, steps=steps, reduced=reduced, seq_len=seq_len,
        per_worker_batch=per_worker_batch, num_spatial=num_spatial,
        ckpt_dir=ckpt_dir, seed=seed, comm=comm)
    return run(spec, verbose=verbose).record


# (flag attribute, dotted spec path) — None-defaulted flags are applied
# only when the user passed them, so scenario presets keep their values
_COMMON_FLAGS = [
    ("algorithm", "algo.algorithm"), ("workers", "data.num_workers"),
    ("seed", "run.seed"), ("tau", "algo.tau"), ("out", "run.out"),
    ("compressor", "comm.compressor"), ("topk_ratio", "comm.topk_ratio"),
    ("channel", "comm.channel"), ("drop_prob", "comm.drop_prob"),
    ("snr_db", "comm.snr_db"), ("byzantine", "comm.byzantine"),
    ("byzantine_mode", "comm.byzantine_mode"),
    ("byzantine_scale", "comm.byzantine_scale"),
    ("aggregator", "comm.aggregator"), ("trim_ratio", "comm.trim_ratio"),
    ("downlink_compressor", "comm.downlink_compressor"),
    ("fading", "comm.fading"), ("doppler_rho", "comm.doppler_rho"),
    ("pathloss_spread_db", "comm.pathloss_spread_db"),
    ("outage_snr_db", "comm.outage_snr_db"),
    ("num_tiers", "comm.num_tiers"), ("tier_rank", "comm.tier_rank"),
    ("round_deadline_s", "comm.round_deadline_s"),
    ("staleness_gamma", "comm.staleness_gamma"), ("quorum", "comm.quorum"),
    ("fault_prob", "comm.fault_prob"), ("fault_rounds", "comm.fault_rounds"),
    ("fault_seed", "comm.fault_seed"),
]
_PAPER_FLAGS = [
    ("case", "data.case"), ("dataset", "data.dataset"),
    ("rounds", "run.rounds"), ("model", "model.name"),
    ("width_mult", "model.width_mult"),
]
_MESH_FLAGS = [
    ("arch", "model.name"), ("steps", "run.rounds"),
    ("ckpt_dir", "run.ckpt_dir"),
]


def _obs_overrides(args: argparse.Namespace) -> list[str]:
    """--obs / --obs-dir / --profile-dir -> run.obs.* overrides (any of
    them switches the telemetry bus on). getattr-safe so programmatic
    Namespace callers without the flags keep working."""
    ovr = []
    obs_dir = getattr(args, "obs_dir", None)
    profile_dir = getattr(args, "profile_dir", None)
    if getattr(args, "obs", False) or obs_dir or profile_dir:
        ovr.append("run.obs.enabled=true")
    if obs_dir:
        ovr.append(f"run.obs.dir={obs_dir}")
    if profile_dir:
        ovr.append(f"run.obs.profile_dir={profile_dir}")
    return ovr


def build_spec_from_args(args: argparse.Namespace) -> ExperimentSpec:
    """scenario preset (or mode default) -> legacy flags -> --set."""
    if args.scenario:
        spec = get_scenario(args.scenario)
    elif args.mode == "mesh":
        spec = spec_from_mesh_kwargs(arch=args.arch or "smollm-360m")
    else:
        spec = ExperimentSpec()
    paper = spec.model.kind == "paper"
    # fail fast on explicitly-passed flags the spec kind cannot honor
    # (silently dropping --rounds on a mesh scenario fakes a longer run)
    wrong_kind = [attr for attr, _ in (_MESH_FLAGS if paper
                                       else _PAPER_FLAGS)
                  if getattr(args, attr) is not None]
    if wrong_kind:
        names = ", ".join("--" + a.replace("_", "-") for a in wrong_kind)
        raise ValueError(
            f"{names} does not apply to a {spec.model.kind!r} spec "
            f"({'use --steps/--arch' if not paper else 'use --rounds'} "
            f"or a --set override instead)")
    for attr, path in _COMMON_FLAGS + (_PAPER_FLAGS if paper
                                       else _MESH_FLAGS):
        v = getattr(args, attr)
        if v is not None:
            spec = override(spec, f"{path}={v}")
    if args.no_error_feedback:
        spec = override(spec, "comm.error_feedback=false")
    if args.adaptive_bits:
        spec = override(spec, "comm.adaptive_bits=true")
    for assignment in _obs_overrides(args):
        spec = override(spec, assignment)
    for assignment in args.overrides:
        spec = override(spec, assignment)
    return spec.validate()


def build_sweep_specs(args: argparse.Namespace) -> list[ExperimentSpec]:
    """--sweep grid: scenario presets x --sweep-axis value lists, with
    any --set overrides applied to every cell. The full paper grid is
    one command:

        python -m repro.launch.train --sweep \\
            paper/fig3-iid,paper/fig3-noniid1,paper/fig3-noniid2 \\
            --sweep-axis algo.algorithm=fedavg,dsl,multi_dsl,mdsl \\
            --seeds 0,1,2,3,4 --jobs 8
    """
    names = [n.strip() for n in args.sweep.split(",") if n.strip()]
    if not names:
        raise ValueError("--sweep needs at least one scenario name")
    specs = [get_scenario(n) for n in names]
    for assignment in args.overrides:
        specs = [override(s, assignment) for s in specs]
    for axis in args.sweep_axis:
        path, eq, raw = axis.partition("=")
        values = [v.strip() for v in raw.split(",") if v.strip()]
        if not eq or not values:
            raise ValueError(f"--sweep-axis must look like "
                             f"key=v1,v2,..., got {axis!r}")
        specs = [override(s, f"{path}={v}") for s in specs for v in values]
    for assignment in _obs_overrides(args):
        specs = [override(s, assignment) for s in specs]
    return [s.validate() for s in specs]


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Run one experiment: --scenario NAME [--set k=v ...], "
                    "or the legacy per-axis flags.")
    ap.add_argument("--scenario", default=None,
                    help="named preset from repro.experiments.registry")
    ap.add_argument("--set", dest="overrides", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="dotted spec override, e.g. comm.compressor=topk "
                         "(repeatable)")
    ap.add_argument("--list-scenarios", action="store_true")
    ap.add_argument("--mode", default="paper", choices=["paper", "mesh"],
                    help="default spec kind when no --scenario is given")
    # paper mode
    ap.add_argument("--algorithm", default=None,
                    choices=["fedavg", "dsl", "multi_dsl", "mdsl"])
    ap.add_argument("--case", default=None, choices=list(PARTITION_CASES))
    ap.add_argument("--dataset", default=None, choices=list(PAPER_DATASETS))
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--model", default=None, choices=["cnn", "resnet"])
    ap.add_argument("--width-mult", type=int, default=None)
    ap.add_argument("--tau", type=float, default=None)
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument("--out", default=None)
    # comm (both modes)
    ap.add_argument("--compressor", default=None, choices=list(COMPRESSORS))
    ap.add_argument("--topk-ratio", type=float, default=None)
    ap.add_argument("--no-error-feedback", action="store_true")
    ap.add_argument("--channel", default=None, choices=list(CHANNELS))
    ap.add_argument("--drop-prob", type=float, default=None)
    ap.add_argument("--snr-db", type=float, default=None)
    ap.add_argument("--byzantine", type=int, default=None)
    ap.add_argument("--byzantine-mode", default=None,
                    choices=list(BYZANTINE_MODES))
    ap.add_argument("--byzantine-scale", type=float, default=None)
    ap.add_argument("--aggregator", default=None, choices=list(AGGREGATORS))
    ap.add_argument("--trim-ratio", type=float, default=None)
    ap.add_argument("--downlink-compressor", default=None,
                    choices=list(COMPRESSORS))
    ap.add_argument("--adaptive-bits", action="store_true")
    # physical layer (comm.phy)
    ap.add_argument("--fading", default=None, choices=list(FADING_MODELS))
    ap.add_argument("--doppler-rho", type=float, default=None)
    ap.add_argument("--pathloss-spread-db", type=float, default=None)
    ap.add_argument("--outage-snr-db", type=float, default=None)
    ap.add_argument("--num-tiers", type=int, default=None)
    ap.add_argument("--tier-rank", default=None, choices=list(TIER_RANKS))
    # straggler / deadline engine + fault injection (comm.straggler)
    ap.add_argument("--round-deadline-s", type=float, default=None)
    ap.add_argument("--staleness-gamma", type=float, default=None)
    ap.add_argument("--quorum", type=int, default=None)
    ap.add_argument("--fault-prob", type=float, default=None)
    ap.add_argument("--fault-rounds", type=int, default=None)
    ap.add_argument("--fault-seed", type=int, default=None)
    # mesh mode
    ap.add_argument("--arch", default=None)
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    # observability (repro.obs; any of these enables the event stream)
    ap.add_argument("--obs", action="store_true",
                    help="stream typed telemetry events to a JSONL file "
                         "under artifacts/obs/ (tail it with "
                         "python -m repro.launch.monitor --follow)")
    ap.add_argument("--obs-dir", default=None,
                    help="event stream directory (implies --obs)")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax.profiler trace for a window of "
                         "rounds into this dir (implies --obs; load in "
                         "TensorBoard)")
    # sweep mode: --sweep S1,S2 [--sweep-axis k=v1,v2]... [--seeds ..]
    ap.add_argument("--sweep", default=None, metavar="SCENARIOS",
                    help="comma-separated scenario names to sweep "
                         "(each crossed with --sweep-axis values, "
                         "--seeds, and any --set overrides)")
    ap.add_argument("--sweep-axis", action="append", default=[],
                    metavar="KEY=V1,V2,...",
                    help="cross-product axis over a dotted spec path, "
                         "e.g. algo.algorithm=fedavg,dsl,multi_dsl,mdsl "
                         "(repeatable)")
    ap.add_argument("--seeds", default=None,
                    help="comma-separated seeds for --sweep (default 0)")
    ap.add_argument("--jobs", type=int, default=1,
                    help="process-pool fan-out for --sweep (1 = serial)")
    args = ap.parse_args()

    if args.list_scenarios:
        width = max(len(n) for n, _ in describe_scenarios())
        for name, what in describe_scenarios():
            print(f"{name.ljust(width)}  {what}")
        return

    if args.sweep:
        # same fail-fast contract as single runs: a per-axis flag that
        # --sweep would silently drop fakes results for a config the
        # user never ran — demand the --set / --sweep-axis spelling
        stray = [attr for attr, _ in
                 _COMMON_FLAGS + _PAPER_FLAGS + _MESH_FLAGS
                 if getattr(args, attr) is not None]
        stray += [f for f in ("no_error_feedback", "adaptive_bits")
                  if getattr(args, f)]
        if stray:
            names = ", ".join("--" + a.replace("_", "-") for a in stray)
            ap.error(f"{names} does not combine with --sweep — spell "
                     f"shared values as --set key=value and swept values "
                     f"as --sweep-axis key=v1,v2")
        try:
            specs = build_sweep_specs(args)
            seeds = ([int(s) for s in args.seeds.split(",") if s.strip()]
                     if args.seeds else [0])
        except ValueError as e:
            ap.error(str(e))
        results = sweep(specs, seeds=seeds, jobs=args.jobs)
        print(f"swept {len(results)} runs "
              f"({len(specs)} specs x {len(seeds)} seeds, "
              f"jobs={args.jobs})")
        return

    try:
        # fail fast at the CLI, not deep inside the first jitted round
        spec = build_spec_from_args(args)
    except ValueError as e:
        ap.error(str(e))

    result = run(spec)
    out = default_out(spec)
    result.save(out)
    print(f"wrote {out}")
    if result.events_path:
        print(f"events {result.events_path}\n"
              f"  view: python -m repro.launch.monitor "
              f"{result.events_path}")


if __name__ == "__main__":
    main()
