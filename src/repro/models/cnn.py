"""Paper-experiment models: the 5-layer CNN of DSL [9] and a compact
ResNet (stand-in for ResNet18 at CPU-tractable width), pure JAX.

Models are (init, apply) pairs over nested-dict params; apply maps
(params, x[N,H,W,C]) -> logits[N,L]. Widths are configurable so the C=50
vmap'ed swarm stays fast on one CPU core while keeping the architecture
shape of the paper's models.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


class ImageModel(NamedTuple):
    init: Callable[[Array], PyTree]
    apply: Callable[[PyTree, Array], Array]
    name: str


def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    w = jax.random.normal(key, (kh, kw, cin, cout)) * jnp.sqrt(2.0 / fan_in)
    return {"w": w, "b": jnp.zeros((cout,))}


def _dense_init(key, din, dout):
    w = jax.random.normal(key, (din, dout)) * jnp.sqrt(2.0 / din)
    return {"w": w, "b": jnp.zeros((dout,))}


def _conv(p, x, stride=1):
    y = jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def _dense(p, x):
    return x @ p["w"] + p["b"]


def _maxpool(x, k=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, k, k, 1), "VALID")


def _meanpool_all(x):
    return x.mean(axis=(1, 2))


def make_cnn5(height: int, width: int, channels: int, num_classes: int,
              width_mult: int = 8) -> ImageModel:
    """Five-layer CNN [9]: conv-pool, conv-pool, conv, dense, dense."""
    c1, c2, c3 = width_mult, 2 * width_mult, 2 * width_mult
    h3, w3 = height // 4, width // 4
    feat = h3 * w3 * c3
    hidden = 4 * width_mult

    def init(key: Array) -> PyTree:
        ks = jax.random.split(key, 5)
        return {
            "conv1": _conv_init(ks[0], 3, 3, channels, c1),
            "conv2": _conv_init(ks[1], 3, 3, c1, c2),
            "conv3": _conv_init(ks[2], 3, 3, c2, c3),
            "fc1": _dense_init(ks[3], feat, hidden),
            "fc2": _dense_init(ks[4], hidden, num_classes),
        }

    def apply(params: PyTree, x: Array) -> Array:
        x = _maxpool(jax.nn.relu(_conv(params["conv1"], x)))
        x = _maxpool(jax.nn.relu(_conv(params["conv2"], x)))
        x = jax.nn.relu(_conv(params["conv3"], x))
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(_dense(params["fc1"], x))
        return _dense(params["fc2"], x)

    return ImageModel(init=init, apply=apply, name=f"cnn5_w{width_mult}")


def make_resnet(height: int, width: int, channels: int, num_classes: int,
                width_mult: int = 8, blocks_per_stage: int = 2) -> ImageModel:
    """Compact pre-activation ResNet (2 stages x `blocks_per_stage` residual
    blocks) — the paper's ResNet18 scaled to CPU width. Uses GroupNorm-free
    residual blocks (normalization-free scaling) to stay vmap-friendly."""
    c1, c2 = width_mult, 2 * width_mult

    def block_init(key, cin, cout, idx):
        k1, k2, k3 = jax.random.split(key, 3)
        p = {"conv_a": _conv_init(k1, 3, 3, cin, cout),
             "conv_b": _conv_init(k2, 3, 3, cout, cout)}
        if cin != cout:
            p["proj"] = _conv_init(k3, 1, 1, cin, cout)
        return p

    def block_apply(p, x, stride):
        h = jax.nn.relu(_conv(p["conv_a"], x, stride))
        h = _conv(p["conv_b"], h)
        skip = _conv(p["proj"], x, stride) if "proj" in p else x
        return jax.nn.relu(skip + 0.5 * h)

    def init(key: Array) -> PyTree:
        n = 2 + 2 * blocks_per_stage
        ks = jax.random.split(key, n)
        p = {"stem": _conv_init(ks[0], 3, 3, channels, c1)}
        cin = c1
        i = 1
        for stage, cout in enumerate((c1, c2)):
            for b in range(blocks_per_stage):
                p[f"s{stage}b{b}"] = block_init(ks[i], cin, cout, i)
                cin = cout
                i += 1
        p["head"] = _dense_init(ks[i], c2, num_classes)
        return p

    def apply(params: PyTree, x: Array) -> Array:
        x = jax.nn.relu(_conv(params["stem"], x))
        for stage in range(2):
            for b in range(blocks_per_stage):
                stride = 2 if (b == 0 and stage > 0) else 1
                x = block_apply(params[f"s{stage}b{b}"], x, stride)
        return _dense(params["head"], _meanpool_all(x))

    return ImageModel(init=init, apply=apply, name=f"resnet_w{width_mult}")
