"""Shared transformer layers, pure JAX (init/apply pairs over dict params).

Conventions
-----------
* params are nested dicts of jnp arrays; init fns take (key, cfg) and
  return params; apply fns take (params, x, ...) and are shape-polymorphic.
* activations: x is (B, S, D). Attention internals are (B, S, H, hd).
* logical sharding axes are annotated via repro.sharding.shard (no-op
  without an active mesh).
* dtype policy: matmuls run in the config dtype (bf16 on TPU), softmax,
  norms and recurrent states in fp32.
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.sharding import shard

Array = jax.Array
PyTree = Any


def cdtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def dense_init(key: Array, shape: tuple[int, ...], in_axis: int = 0,
               dtype=jnp.float32) -> Array:
    fan_in = shape[in_axis]
    return (jax.random.normal(key, shape) / math.sqrt(fan_in)).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32) -> PyTree:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: PyTree, x: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

def embedding_init(key: Array, vocab: int, d: int, dtype=jnp.float32) -> PyTree:
    tbl = jax.random.normal(key, (vocab, d)) * 0.01
    return {"table": tbl.astype(dtype)}


def embed(params: PyTree, tokens: Array) -> Array:
    tbl = shard(params["table"], ("vocab", "embed"))
    out = jnp.take(tbl, tokens, axis=0)
    return shard(out, ("batch", "seq", "embed"))


def unembed(params: PyTree, x: Array) -> Array:
    """Tied output head: (B,S,D) @ (V,D)^T -> (B,S,V)."""
    tbl = shard(params["table"], ("vocab", "embed"))
    logits = jnp.einsum("bsd,vd->bsv", x, tbl)
    return shard(logits, ("batch", "seq", "vocab"))


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, H, hd); positions: (B, S) or (S,). Applies RoPE in fp32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) *
                    (math.log(theta) / half))
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA; full-causal / sliding-window; train, prefill, decode)
# ---------------------------------------------------------------------------

def attention_init(key: Array, cfg, cross: bool = False) -> PyTree:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, k = cfg.num_heads, cfg.num_kv_heads
    dt = cdtype(cfg)
    ks = jax.random.split(key, 5)
    return {
        "norm": rmsnorm_init(d),
        "wq": dense_init(ks[0], (d, h, hd), dtype=dt),
        "wk": dense_init(ks[1], (d, k, hd), dtype=dt),
        "wv": dense_init(ks[2], (d, k, hd), dtype=dt),
        "wo": dense_init(ks[3], (h, hd, d), dtype=dt),
    }


def _shard_qkv(q, k, v):
    # act_* names: activation head sharding is decoupled from the WEIGHT
    # head sharding so serving can seq-shard the KV cache (act heads
    # replicated) while keeping projection weights TP-sharded
    q = shard(q, ("batch", "seq", "act_heads", "head_dim"))
    k = shard(k, ("batch", "seq", "act_kv_heads", "head_dim"))
    v = shard(v, ("batch", "seq", "act_kv_heads", "head_dim"))
    return q, k, v


def _repeat_kv(k: Array, q_per_kv: int) -> Array:
    """(B,S,K,hd) -> (B,S,K*q_per_kv,hd) by repetition (GQA)."""
    if q_per_kv == 1:
        return k
    return jnp.repeat(k, q_per_kv, axis=2)


def chunked_attention(q: Array, k: Array, v: Array, *, causal: bool,
                      window: int = 0, q_offset: Array | int = 0,
                      kv_len: Optional[Array] = None,
                      q_chunk: int = 512, kv_chunk: int = 1024) -> Array:
    """Memory-bounded multi-head attention (flash-style, pure JAX).

    q: (B, Sq, H, hd); k/v: (B, Sk, H, hd) (kv already GQA-repeated).
    Scans over kv chunks with an online softmax so the (Sq, Sk) score
    matrix is never materialized beyond (q_chunk, kv_chunk) tiles. This is
    the XLA-lowered twin of kernels/flash_attention (same tiling), used
    whenever we need a CPU-lowerable path (dry-run) — see DESIGN.md §5.

    window > 0 restricts to a sliding causal window. kv_len masks out
    cache positions >= kv_len (decode with a partially filled cache).
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    orig_dtype = q.dtype

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    nq = -(-Sq // q_chunk)
    nk = -(-Sk // kv_chunk)
    # pad to chunk multiples
    q = jnp.pad(q, ((0, 0), (0, nq * q_chunk - Sq), (0, 0), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, nk * kv_chunk - Sk), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, nk * kv_chunk - Sk), (0, 0), (0, 0)))

    q = q.reshape(B, nq, q_chunk, H, hd)
    k = k.reshape(B, nk, kv_chunk, H, hd)
    v = v.reshape(B, nk, kv_chunk, H, hd)

    q_pos = (jnp.arange(nq * q_chunk).reshape(nq, q_chunk) +
             jnp.asarray(q_offset))
    k_pos = jnp.arange(nk * kv_chunk).reshape(nk, kv_chunk)
    k_valid = k_pos < (Sk if kv_len is None else kv_len)

    def q_block(qi_and_pos):
        qi, qpos = qi_and_pos  # (B,qc,H,hd), (qc,)

        def kv_block(carry, kj):
            acc, m, l = carry
            kjv, vjv, kpos, kval = kj
            s = jnp.einsum("bqhd,bkhd->bhqk", qi, kjv,
                           preferred_element_type=jnp.float32) * scale
            mask = kval[None, :]
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            if window:
                mask = mask & (kpos[None, :] > qpos[:, None] - window)
            s = jnp.where(mask[None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # guard fully-masked rows
            m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(mask[None, None], p, 0.0)
            alpha = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_safe))
            l_new = alpha * l + p.sum(axis=-1)
            acc_new = (acc * alpha[..., None] +
                       jnp.einsum("bhqk,bkhd->bhqd", p,
                                  vjv.astype(jnp.float32)))
            return (acc_new, m_new, l_new), None

        init = (jnp.zeros((B, H, q_chunk, hd), jnp.float32),
                jnp.full((B, H, q_chunk), -jnp.inf),
                jnp.zeros((B, H, q_chunk), jnp.float32))
        (acc, m, l), _ = jax.lax.scan(
            kv_block, init,
            (k.swapaxes(0, 1), v.swapaxes(0, 1), k_pos, k_valid))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.swapaxes(1, 2)  # (B,qc,H,hd)

    out = jax.lax.map(q_block, (q.swapaxes(0, 1), q_pos))  # (nq,B,qc,H,hd)
    out = out.swapaxes(0, 1).reshape(B, nq * q_chunk, H, hd)[:, :Sq]
    return out.astype(orig_dtype)


def attention_apply(params: PyTree, x: Array, cfg, *, mode: str,
                    layer_cache: Optional[PyTree] = None,
                    positions: Optional[Array] = None,
                    window: int = 0,
                    memory_kv: Optional[tuple[Array, Array]] = None,
                    attn_impl: str = "chunked",
                    ) -> tuple[Array, Optional[PyTree]]:
    """One attention sub-block (pre-norm, residual added by caller).

    mode: "train" | "prefill" | "decode" | "encode" (bidirectional).
    For cross-attention pass memory_kv=(k_mem, v_mem) and mode="train"/
    "decode"; q comes from x, no cache update.

    layer_cache (self-attn decode/prefill): dict with
      k, v: (B, S_cache, K, hd)   (S_cache = window for swa ring buffer)
      pos:  () int32 — number of tokens already written.
    Returns (out, new_cache).
    """
    B, S, D = x.shape
    h = rmsnorm(params["norm"], x, cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, params["wq"])
    if memory_kv is None:
        k = jnp.einsum("bsd,dhk->bshk", h, params["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, params["wv"])
        q, k, v = _shard_qkv(q, k, v)
    else:
        k, v = memory_kv

    if positions is None:
        base = 0 if layer_cache is None else layer_cache["pos"]
        positions = base + jnp.arange(S)[None, :]

    if memory_kv is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if mode in ("train", "encode") or (mode == "prefill" and layer_cache is None):
        pass  # use k, v as computed
    elif mode == "prefill":
        # write the whole sequence into the cache (ring for swa)
        cache_len = layer_cache["k"].shape[1]
        if window and cache_len < S:
            # keep the last `cache_len` tokens
            kk, vv = k[:, -cache_len:], v[:, -cache_len:]
            idx = (positions[0, -cache_len:]) % cache_len
        else:
            kk, vv = k, v
            idx = positions[0, :] % cache_len
        ck = layer_cache["k"].at[:, idx].set(kk.astype(layer_cache["k"].dtype))
        cv = layer_cache["v"].at[:, idx].set(vv.astype(layer_cache["v"].dtype))
        new_cache = {"k": ck, "v": cv, "pos": layer_cache["pos"] + S}
    elif mode == "decode" and memory_kv is None:
        cache_len = layer_cache["k"].shape[1]
        idx = positions[0, :] % cache_len
        ck = layer_cache["k"].at[:, idx].set(k.astype(layer_cache["k"].dtype))
        cv = layer_cache["v"].at[:, idx].set(v.astype(layer_cache["v"].dtype))
        ck = shard(ck, ("cache_batch", "cache_seq", "act_kv_heads",
                        "head_dim"))
        cv = shard(cv, ("cache_batch", "cache_seq", "act_kv_heads",
                        "head_dim"))
        new_cache = {"k": ck, "v": cv, "pos": layer_cache["pos"] + S}
        k, v = ck, cv  # same dtype as q (bf16) — no cast, nothing to hoist

    qkv_ratio = cfg.num_heads // k.shape[2]

    if mode == "decode" and memory_kv is None:
        # One-token attention over the cache. Grouped-GQA einsum: no
        # _repeat_kv (which materializes q_per_kv copies of the cache)
        # and no f32 cast of v (XLA hoists that cast out of the layer
        # scan into an f32 copy of the WHOLE stacked cache — +12 GiB on
        # deepseek-67b decode_32k, EXPERIMENTS.md §Perf iteration 4).
        cache_len = k.shape[1]
        kv_pos = jnp.arange(cache_len)
        cur = layer_cache["pos"] + S - 1  # position of the new token
        if window and cache_len <= window:
            # ring buffer: entry j holds absolute position p iff p % len == j
            # valid if written (p<=cur) and within window
            valid = kv_pos <= (cur % cache_len)
            wrapped = cur >= cache_len
            valid = valid | wrapped  # after wrap, all slots hold valid entries
            scores_mask = valid
        else:
            scores_mask = kv_pos <= cur
            if window:
                abs_pos = kv_pos
                scores_mask = scores_mask & (abs_pos > cur - window)
        B, _, H, hd = q.shape
        K = k.shape[2]
        qg = q.reshape(B, S, K, H // K, hd)
        s = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                       preferred_element_type=jnp.float32)
        s = s / math.sqrt(hd)
        s = jnp.where(scores_mask[None, None, None, None, :], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgst,btkh->bskgh", p.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        out = out.reshape(B, S, H, hd).astype(x.dtype)
    else:
        causal = mode != "encode" and memory_kv is None
        q_off = 0
        if mode == "decode" and memory_kv is not None:
            q_off = 0  # cross-attn: no causal mask anyway
        out = chunked_attention(q, _repeat_kv(k, qkv_ratio),
                                _repeat_kv(v, qkv_ratio), causal=causal,
                                window=window, q_offset=q_off)

    out = shard(out, ("batch", "seq", "act_heads", "head_dim"))
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return shard(y, ("batch", "seq", "embed")), new_cache


def init_attention_cache(cfg, batch: int, cache_len: int, window: int,
                         dtype) -> PyTree:
    k_heads, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    size = min(cache_len, window) if window else cache_len
    return {
        "k": jnp.zeros((batch, size, k_heads, hd), dtype),
        "v": jnp.zeros((batch, size, k_heads, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# gated MLP
# ---------------------------------------------------------------------------

def mlp_init(key: Array, d: int, d_ff: int, cfg) -> PyTree:
    dt = cdtype(cfg)
    ks = jax.random.split(key, 3)
    return {
        "norm": rmsnorm_init(d),
        "wi": dense_init(ks[0], (d, d_ff), dtype=dt),    # gate
        "wu": dense_init(ks[1], (d, d_ff), dtype=dt),    # up
        "wo": dense_init(ks[2], (d_ff, d), dtype=dt),    # down
    }


def mlp_apply(params: PyTree, x: Array, cfg) -> Array:
    h = rmsnorm(params["norm"], x, cfg.norm_eps)
    wi = shard(params["wi"], ("embed_fsdp", "mlp"))
    wu = shard(params["wu"], ("embed_fsdp", "mlp"))
    wo = shard(params["wo"], ("mlp", "embed_fsdp"))
    a = jax.nn.silu(jnp.einsum("bsd,df->bsf", h, wi))
    b = jnp.einsum("bsd,df->bsf", h, wu)
    y = jnp.einsum("bsf,fd->bsd", a * b, wo)
    return shard(y, ("batch", "seq", "embed"))
