"""Mixture-of-Experts channel mixer (top-k routing, sort-based dispatch).

TPU-native dispatch (DESIGN.md §5): tokens are argsorted by expert
assignment, packed into per-expert capacity buffers, run through a single
vmapped expert FFN einsum (MXU-friendly (E, cap, d) x (E, d, f)), and
scatter-combined back weighted by the router gate. Capacity-overflow
tokens are dropped (standard GShard semantics, capacity_factor
configurable). With the expert dim sharded over the mesh "expert" axis
(rules table: the data axis in FSDP mode) the pack/unpack gathers lower
to all-to-all-style collectives — the communication pattern the roofline
tracks for the MoE architectures.

Router load-balancing: the auxiliary loss of Shazeer et al. (mean gate
fraction x mean dispatch fraction per expert) is returned alongside the
output so the trainer can add it to the task loss.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import cdtype, dense_init, rmsnorm, rmsnorm_init
from repro.sharding import shard

Array = jax.Array
PyTree = Any


def moe_init(key: Array, cfg) -> PyTree:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    dt = cdtype(cfg)
    ks = jax.random.split(key, 5)
    p = {
        "norm": rmsnorm_init(d),
        "router": dense_init(ks[0], (d, E), dtype=jnp.float32),
        "wi": dense_init(ks[1], (E, d, f), in_axis=1, dtype=dt),
        "wu": dense_init(ks[2], (E, d, f), in_axis=1, dtype=dt),
        "wo": dense_init(ks[3], (E, f, d), in_axis=1, dtype=dt),
    }
    if cfg.dense_residual:  # arctic: parallel dense MLP
        kd = jax.random.split(ks[4], 3)
        p["dense"] = {
            "wi": dense_init(kd[0], (d, f), dtype=dt),
            "wu": dense_init(kd[1], (d, f), dtype=dt),
            "wo": dense_init(kd[2], (f, d), dtype=dt),
        }
    return p


def moe_apply(params: PyTree, x: Array, cfg) -> tuple[Array, Array]:
    """Returns (y, aux_loss). x: (B, S, D)."""
    capacity_factor = cfg.moe_capacity_factor
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    h = rmsnorm(params["norm"], x, cfg.norm_eps)

    # expert-parallel all-to-all dispatch (shard_map) when the rules
    # enable it — see moe_ep.py; falls through to the GSPMD sort-based
    # dispatch otherwise (CPU tests / vmapped tp-mode swarm)
    from repro.models import moe_ep
    from repro.sharding.rules import get_rules
    rules, mesh = get_rules()
    ep_axis = moe_ep.ep_applicable(cfg, mesh, rules)
    if ep_axis is not None and B % mesh.shape[ep_axis] == 0:
        y, aux_loss = moe_ep.moe_apply_ep(params, h, cfg, mesh, ep_axis)
        if "dense" in params:  # arctic dense residual
            dp = params["dense"]
            a = jax.nn.silu(jnp.einsum("bsd,df->bsf", h, dp["wi"]))
            u = jnp.einsum("bsd,df->bsf", h, dp["wu"])
            y = y + jnp.einsum("bsf,fd->bsd", a * u, dp["wo"])
        return shard(y, ("batch", "seq", "embed")), aux_loss
    hf = h.reshape(B * S, D)
    T = B * S

    logits = (hf.astype(jnp.float32) @ params["router"])         # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)              # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)                  # renorm

    # auxiliary load-balance loss (Shazeer): E * sum_e f_e * p_e
    dispatch_frac = jnp.zeros((E,), jnp.float32).at[
        expert_idx.reshape(-1)].add(1.0) / (T * K)
    gate_frac = probs.mean(axis=0)
    aux_loss = E * jnp.sum(dispatch_frac * gate_frac)

    cap = int(math.ceil(T * K / E * capacity_factor))
    cap = max(cap, 1)

    # --- pack: sort (token, k) pairs by expert, take first `cap` each ---
    flat_e = expert_idx.reshape(T * K)                           # (TK,)
    sort_idx = jnp.argsort(flat_e)                               # (TK,)
    sorted_e = flat_e[sort_idx]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * K) - starts[sorted_e]                   # rank in expert
    keep = pos < cap
    dest = jnp.where(keep, sorted_e * cap + pos, E * cap)        # drop slot
    src_token = sort_idx // K

    buf = jnp.zeros((E * cap + 1, D), h.dtype)
    buf = buf.at[dest].set(hf[src_token])
    # pin the scatter itself to a replicated layout: XLA's SPMD scatter
    # partitioning miscompiles when the expert axis of `buf` is sharded
    # over a mesh dim (observed on the (data, model) host mesh); the
    # reshard to the expert-sharded FFN layout happens on `xs` below
    buf = shard(buf, (None, "embed"))
    xs = buf[: E * cap].reshape(E, cap, D)
    xs = shard(xs, ("expert", None, "embed"))

    # --- expert FFN (gated) ---
    wi = shard(params["wi"], ("expert", "embed_fsdp", "expert_mlp"))
    wu = shard(params["wu"], ("expert", "embed_fsdp", "expert_mlp"))
    wo = shard(params["wo"], ("expert", "expert_mlp", "embed_fsdp"))
    a = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xs, wi))
    u = jnp.einsum("ecd,edf->ecf", xs, wu)
    ys = jnp.einsum("ecf,efd->ecd", a * u, wo)                   # (E,cap,D)
    ys = shard(ys, ("expert", None, "embed"))

    # --- combine: gather back, weight by gate, sum over k ---
    ys_flat = jnp.concatenate(
        [ys.reshape(E * cap, D), jnp.zeros((1, D), ys.dtype)], axis=0)
    # replicated gather for the same partitioner reason as the scatter
    ys_flat = shard(ys_flat, (None, "embed"))
    slot_of_sorted = jnp.where(keep, dest, E * cap)
    # invert the sort: slot of flat (token,k) pair j is slot_of_sorted[rank_j]
    inv = jnp.zeros((T * K,), jnp.int32).at[sort_idx].set(
        jnp.arange(T * K, dtype=jnp.int32))
    slot = slot_of_sorted[inv].reshape(T, K)
    contrib = ys_flat[slot]                                      # (T,K,D)
    yf = jnp.einsum("tkd,tk->td", contrib.astype(jnp.float32),
                    gate_vals).astype(x.dtype)
    y = yf.reshape(B, S, D)

    if "dense" in params:  # arctic dense residual
        dp = params["dense"]
        a = jax.nn.silu(jnp.einsum("bsd,df->bsf", h, dp["wi"]))
        u = jnp.einsum("bsd,df->bsf", h, dp["wu"])
        y = y + jnp.einsum("bsf,fd->bsd", a * u, dp["wo"])

    return shard(y, ("batch", "seq", "embed")), aux_loss
