"""Expert-parallel MoE dispatch via shard_map + all-to-all.

GSPMD lowers the sort-based dispatch of `moe.moe_apply` (a cross-shard
scatter) as "replicate + combine-all-reduce": per-device u32/f32 buffers
of shape (T·K, d_model) and an all-reduce of the same size per MoE layer
— 7–8.75 GiB each for arctic-480b train_4k (EXPERIMENTS.md §Perf
iteration 5). The textbook expert-parallel pattern exchanges only
capacity-bounded buffers:

  1. per token-shard: route, pack tokens by destination expert shard
     into (n_shards, cap_send, D),
  2. `jax.lax.all_to_all` over the expert axis,
  3. local pack by local expert id -> (E_local, cap_local, D), run the
     expert FFN, un-pack,
  4. all-to-all back, combine with router gates at the origin.

Per-device traffic: Θ(T·K·cf·D / n) instead of Θ(T·K·D).

Everything is shape-static (GShard capacity semantics, overflow drops at
both the send and the local stage); `auto` axes (model / pod) remain
under GSPMD, so the expert-FFN f dim stays tensor-parallel inside the
manual region.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.6 exposes shard_map at the top level
    from jax import shard_map
except ImportError:  # older jax: adapt the experimental API
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma=True):
        """Compat shim for the experimental API (`check_vma` maps onto
        `check_rep`). Partial-auto regions (`axis_names` a strict subset
        of the mesh) crash old XLA's SPMD partitioner ("PartitionId
        instruction is not supported"), so the shim goes fully manual:
        axes the new API would leave to GSPMD are instead replicated at
        region entry per the in_specs — correct, but the expert-FFN f
        dim loses tensor parallelism inside the region on old jax."""
        del axis_names
        return _exp_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_vma)

Array = jax.Array
PyTree = Any


def _pack(ids: Array, n_bins: int, cap: int, payload: PyTree,
          valid: Array | None = None) -> tuple[PyTree, Array]:
    """Pack M items into (n_bins, cap, ...) capacity buffers.

    ids: (M,) int bin per item; payload: pytree of (M, ...) arrays.
    Returns (buffers, slot) where slot[m] = flat index bin*cap+pos of
    item m, or the sentinel n_bins*cap if dropped (overflow / ~valid).
    One argsort serves every payload leaf.
    """
    M = ids.shape[0]
    if valid is not None:
        ids = jnp.where(valid, ids, n_bins)  # sentinel bin
    sort_idx = jnp.argsort(ids)
    sorted_ids = ids[sort_idx]
    counts = jnp.zeros((n_bins + 1,), jnp.int32).at[ids].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(M) - starts[sorted_ids]
    keep = (pos < cap) & (sorted_ids < n_bins)
    dest_slot = jnp.where(keep, sorted_ids * cap + pos, n_bins * cap)

    def pack_leaf(x):
        buf = jnp.zeros((n_bins * cap + 1,) + x.shape[1:], x.dtype)
        buf = buf.at[dest_slot].set(x[sort_idx])
        return buf[: n_bins * cap].reshape((n_bins, cap) + x.shape[1:])

    bufs = jax.tree.map(pack_leaf, payload)
    # slot per ORIGINAL item: invert the sort
    inv = jnp.zeros((M,), jnp.int32).at[sort_idx].set(
        jnp.arange(M, dtype=jnp.int32))
    slot = dest_slot[inv]
    return bufs, slot


def moe_apply_ep(params: PyTree, h: Array, cfg, mesh,
                 axis_name: str) -> tuple[Array, Array]:
    """Expert-parallel MoE over `axis_name`. h: (B, S, D) pre-normed.
    Requires E % n_shards == 0 and B % n_shards == 0."""
    B, S, D = h.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    cf = cfg.moe_capacity_factor
    n = mesh.shape[axis_name]
    E_local = E // n
    T = B * S                       # global tokens
    Tl = T // n                     # per shard
    cap_send = max(int(math.ceil(Tl * K / n * cf)), 1)
    cap_local = max(int(math.ceil(T * K / E * cf)), 1)

    def body(hb, router, wi, wu, wo):
        # hb: (B/n, S, D) local; wi/wu/wo: (E_local, d, f); router (d, E)
        hf = hb.reshape(-1, D)                                   # (Tl, D)
        logits = hf.astype(jnp.float32) @ router                 # (Tl, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, K)          # (Tl, K)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)

        # load-balance aux (global stats via psum)
        local_counts = jnp.zeros((E,), jnp.float32).at[
            expert_idx.reshape(-1)].add(1.0)
        dispatch_frac = jax.lax.psum(local_counts, axis_name) / (T * K)
        gate_frac = jax.lax.psum(probs.sum(axis=0), axis_name) / T
        aux = E * jnp.sum(dispatch_frac * gate_frac)

        # ---- stage 1: pack by destination expert shard ----
        flat_e = expert_idx.reshape(Tl * K)
        dest_shard = flat_e // E_local
        tok = jnp.arange(Tl * K) // K
        send, slot_send = _pack(
            dest_shard, n, cap_send,
            {"x": hf[tok], "e": flat_e.astype(jnp.int32)})
        # empty slots carry e=0 -> mark invalid with a sentinel payload
        ones, _ = _pack(dest_shard, n, cap_send,
                        {"v": jnp.ones((Tl * K,), jnp.int8)})

        # ---- all-to-all to expert owners ----
        a2a = partial(jax.lax.all_to_all, axis_name=axis_name,
                      split_axis=0, concat_axis=0, tiled=True)
        recv_x = a2a(send["x"])                 # (n*cap_send, D) tiled
        recv_e = a2a(send["e"])
        recv_v = a2a(ones["v"])
        rf = recv_x.reshape(n * cap_send, D)
        re = recv_e.reshape(n * cap_send)
        rv = recv_v.reshape(n * cap_send) > 0

        # ---- stage 2: pack by LOCAL expert id ----
        my_shard = jax.lax.axis_index(axis_name)
        local_e = re - my_shard * E_local
        xs, slot_recv = _pack(local_e, E_local, cap_local, {"x": rf},
                              valid=rv & (local_e >= 0)
                              & (local_e < E_local))
        xs = xs["x"]                                            # (El,c,D)

        # ---- expert FFN (f dim stays GSPMD-auto over "model") ----
        act = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xs, wi))
        up = jnp.einsum("ecd,edf->ecf", xs, wu)
        ys = jnp.einsum("ecf,efd->ecd", act * up, wo)           # (El,c,D)

        # ---- inverse: local unpack, all-to-all back, combine ----
        ys_flat = jnp.concatenate(
            [ys.reshape(E_local * cap_local, D),
             jnp.zeros((1, D), ys.dtype)], axis=0)
        back = ys_flat[slot_recv].reshape(n * cap_send, D)
        origin = a2a(back).reshape(n * cap_send, D)
        origin = jnp.concatenate(
            [origin, jnp.zeros((1, D), origin.dtype)], axis=0)
        contrib = origin[slot_send].reshape(Tl, K, D)
        yf = jnp.einsum("tkd,tk->td", contrib.astype(jnp.float32),
                        gate_vals).astype(hb.dtype)
        return yf.reshape(hb.shape), aux

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(axis_name, None, None),   # h: batch over expert axis
                  P(None, None),              # router replicated
                  P(axis_name, None, None),   # wi: experts over axis
                  P(axis_name, None, None),
                  P(axis_name, None, None)),
        out_specs=(P(axis_name, None, None), P()),
        # manual ONLY over the expert axis; model/pod stay GSPMD-auto
        axis_names={axis_name}, check_vma=False)
    return fn(h, params["router"],
              params["wi"], params["wu"], params["wo"])


def ep_applicable(cfg, mesh, rules) -> str | None:
    """Return the EP axis name if the shard_map dispatch applies."""
    if mesh is None or rules is None:
        return None
    if not rules.get("moe_ep", False):
        return None
    axis = rules.get("expert")
    if not isinstance(axis, str) or axis not in mesh.axis_names:
        return None
    n = mesh.shape[axis]
    if n <= 1 or cfg.num_experts % n:
        return None
    return axis
