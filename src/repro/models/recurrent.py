"""Recurrent temporal-mixing blocks: RG-LRU (RecurrentGemma,
arXiv:2402.19427) and xLSTM's mLSTM / sLSTM (arXiv:2405.04517), pure JAX.

All three expose the same interface as attention blocks:
    init(key, cfg) -> params
    apply(params, x, cfg, mode, layer_cache) -> (y, new_cache)
with constant-size recurrent caches (the reason these archs run the
long_500k decode shape).

Parallel-scan strategy (TPU adaptation, DESIGN.md §5):
* RG-LRU is a diagonal linear recurrence  h_t = a_t * h_{t-1} + b_t, so
  train/prefill use jax.lax.associative_scan (log-depth).
* mLSTM's matrix memory is chunk-parallelized: within a chunk the output
  is a masked quadratic form (attention-like, MXU-friendly); across chunks
  a (hd x hd) state is carried. Exponential gating is stabilized in log
  space with a running max, matching the xLSTM paper's formulation.
* sLSTM has a true sequential dependence (recurrent weights act on h_{t-1})
  and cannot be parallelized (xLSTM paper §2.3); train/prefill scan over
  time.
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import cdtype, dense_init, rmsnorm, rmsnorm_init
from repro.sharding import shard

Array = jax.Array
PyTree = Any

# ---------------------------------------------------------------------------
# RG-LRU block (RecurrentGemma recurrent block)
# ---------------------------------------------------------------------------
# x -> norm -> { branch_y = gelu(W_y x) ; branch_x = conv1d_4(W_x x) ->
#   RG-LRU } -> W_o (branch_y * lru_out)
# RG-LRU: r_t = sigmoid(W_r u + b_r); i_t = sigmoid(W_i u + b_i)
#         a_t = exp(c * softplus(Lambda) * (-r_t))        (c = 8)
#         h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

_RGLRU_C = 8.0
_CONV_W = 4


def rglru_init(key: Array, cfg) -> PyTree:
    d = cfg.d_model
    dt = cdtype(cfg)
    ks = jax.random.split(key, 7)
    # Lambda init so a^c spans (0.9, 0.999) like the paper
    lam = jax.random.uniform(ks[5], (d,), minval=0.9, maxval=0.999)
    lam_param = jnp.log(jnp.exp(-jnp.log(lam) / _RGLRU_C) - 1.0)  # inv softplus
    return {
        "norm": rmsnorm_init(d),
        "wx": dense_init(ks[0], (d, d), dtype=dt),
        "wy": dense_init(ks[1], (d, d), dtype=dt),
        "wo": dense_init(ks[2], (d, d), dtype=dt),
        "conv": dense_init(ks[3], (_CONV_W, d), dtype=dt) / math.sqrt(_CONV_W),
        "w_r": dense_init(ks[4], (d, d), dtype=dt),
        "w_i": dense_init(ks[6], (d, d), dtype=dt),
        "b_r": jnp.zeros((d,), dt),
        "b_i": jnp.zeros((d,), dt),
        "lam": lam_param.astype(jnp.float32),
    }


def _causal_conv(w: Array, x: Array, state: Optional[Array]
                 ) -> tuple[Array, Array]:
    """Depthwise causal conv, width 4. x: (B,S,D); state: (B, W-1, D)."""
    B, S, D = x.shape
    if state is None:
        state = jnp.zeros((B, _CONV_W - 1, D), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i:i + S] * w[i] for i in range(_CONV_W))
    return out, xp[:, -( _CONV_W - 1):]


def _rglru_gates(params: PyTree, u: Array) -> tuple[Array, Array]:
    """Returns (log_a, beta*i*u) in fp32. u: (B,S,D)."""
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", u, params["w_r"])
                       .astype(jnp.float32) + params["b_r"].astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", u, params["w_i"])
                       .astype(jnp.float32) + params["b_i"].astype(jnp.float32))
    log_a = -_RGLRU_C * jax.nn.softplus(params["lam"]) * r  # (B,S,D) fp32
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    return log_a, beta * i * u.astype(jnp.float32)


def rglru_apply(params: PyTree, x: Array, cfg, *, mode: str,
                layer_cache: Optional[PyTree] = None
                ) -> tuple[Array, Optional[PyTree]]:
    B, S, D = x.shape
    h = rmsnorm(params["norm"], x, cfg.norm_eps)
    y_branch = jax.nn.gelu(jnp.einsum("bsd,de->bse", h, params["wy"]))
    u = jnp.einsum("bsd,de->bse", h, params["wx"])
    conv_state = None if layer_cache is None else layer_cache["conv"]
    u, new_conv = _causal_conv(params["conv"], u, conv_state)
    log_a, b = _rglru_gates(params, u)

    h0 = (jnp.zeros((B, D), jnp.float32) if layer_cache is None
          else layer_cache["h"])

    if mode == "decode" and S == 1:
        a = jnp.exp(log_a[:, 0])
        h_new = a * h0 + b[:, 0]
        states = h_new[:, None]
    else:
        # associative scan over the diagonal recurrence, folding in h0
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2
        a_seq = jnp.exp(log_a)
        # fold initial state into the first step
        b = b.at[:, 0].add(a_seq[:, 0] * h0)
        _, states = jax.lax.associative_scan(combine, (a_seq, b), axis=1)
        h_new = states[:, -1]

    states = shard(states.astype(x.dtype), ("batch", "seq", "embed"))
    out = jnp.einsum("bse,ed->bsd", y_branch * states, params["wo"])
    out = shard(out, ("batch", "seq", "embed"))
    cache = None
    if layer_cache is not None:
        cache = {"h": h_new, "conv": new_conv}
    return out, cache


def init_rglru_cache(cfg, batch: int, dtype) -> PyTree:
    d = cfg.d_model
    return {"h": jnp.zeros((batch, d), jnp.float32),
            "conv": jnp.zeros((batch, _CONV_W - 1, d), dtype)}


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM matrix memory)
# ---------------------------------------------------------------------------
# Recurrence per head (state C: (hd_v, hd_k), n: (hd_k,), m: ()):
#   f_t = sigmoid(f_raw);  i_t = exp(i_raw)    (log-space stabilized)
#   m_t = max(log f_t + m_{t-1}, log i_t)
#   C_t = exp(log f_t + m_{t-1} - m_t) C_{t-1} + exp(log i_t - m_t) v_t k_t^T
#   n_t = ... same ... + exp(log i_t - m_t) k_t
#   h_t = C_t q_t / max(|n_t . q_t|, exp(-m_t))
# Block: norm -> up-proj (expansion 2) -> q,k,v + gates -> recurrence ->
#        out-gate * norm(h) -> down-proj. (Simplified block wiring keeping
#        the memory cell faithful.)

_MLSTM_EXP = 2


def mlstm_init(key: Array, cfg) -> PyTree:
    d = cfg.d_model
    di = _MLSTM_EXP * d
    H = cfg.num_heads
    dt = cdtype(cfg)
    ks = jax.random.split(key, 8)
    return {
        "norm": rmsnorm_init(d),
        "w_up": dense_init(ks[0], (d, di), dtype=dt),
        "w_gate": dense_init(ks[1], (d, di), dtype=dt),
        "mq": dense_init(ks[2], (di, di), dtype=dt),
        "mk": dense_init(ks[3], (di, di), dtype=dt),
        "mv": dense_init(ks[4], (di, di), dtype=dt),
        "w_if": dense_init(ks[5], (di, 2 * H), dtype=dt),
        "b_if": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]).astype(jnp.float32),
        "out_norm": rmsnorm_init(di),
        "w_down": dense_init(ks[6], (di, d), dtype=dt),
    }


def _mlstm_qkvg(params, x, cfg):
    B, S, _ = x.shape
    H = cfg.num_heads
    h = rmsnorm(params["norm"], x, cfg.norm_eps)
    up = jnp.einsum("bsd,de->bse", h, params["w_up"])
    gate = jax.nn.silu(jnp.einsum("bsd,de->bse", h, params["w_gate"]))
    di = up.shape[-1]
    hd = di // H
    q = jnp.einsum("bse,ef->bsf", up, params["mq"]).reshape(B, S, H, hd)
    k = jnp.einsum("bse,ef->bsf", up, params["mk"]).reshape(B, S, H, hd)
    k = k / math.sqrt(hd)
    v = jnp.einsum("bse,ef->bsf", up, params["mv"]).reshape(B, S, H, hd)
    if_raw = (jnp.einsum("bse,eh->bsh", up, params["w_if"])
              .astype(jnp.float32) + params["b_if"])
    log_i = if_raw[..., :H]                      # log input gate (pre-exp)
    log_f = jax.nn.log_sigmoid(if_raw[..., H:])  # log sigmoid forget
    return q, k, v, gate, log_i, log_f


def mlstm_sequential(q, k, v, log_i, log_f, C0, n0, m0):
    """Exact per-step recurrence (reference + decode). Shapes:
    q/k/v (B,S,H,hd); gates (B,S,H); states C (B,H,hd,hd), n (B,H,hd),
    m (B,H). Returns (h (B,S,H,hd), C, n, m)."""

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, li, lf = xs  # (B,H,hd), (B,H)
        m_new = jnp.maximum(lf + m, li)
        fa = jnp.exp(lf + m - m_new)[..., None]
        ia = jnp.exp(li - m_new)[..., None]
        C = fa[..., None] * C + ia[..., None] * (vt[..., None] * kt[..., None, :])
        n = fa * n + ia * kt
        denom = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt)),
                            jnp.exp(-m_new))
        h = jnp.einsum("bhvk,bhk->bhv", C, qt) / denom[..., None]
        return (C, n, m_new), h

    xs = (q.swapaxes(0, 1).astype(jnp.float32),
          k.swapaxes(0, 1).astype(jnp.float32),
          v.swapaxes(0, 1).astype(jnp.float32),
          log_i.swapaxes(0, 1), log_f.swapaxes(0, 1))
    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), xs)
    return hs.swapaxes(0, 1), C, n, m


def mlstm_chunked(q, k, v, log_i, log_f, C0, n0, m0, chunk: int = 256):
    """Chunk-parallel mLSTM: within-chunk masked quadratic form (MXU
    matmuls) + cross-chunk (C, n, m) carry. Exactly equals
    mlstm_sequential (see tests/test_recurrent.py).

    Derivation: unrolling the stabilized recurrence gives, for target t,
      m_t           = max( m_0 + F_t ,  max_{s<=t} A[t,s] )
      C_t q_t       = e^{m_0+F_t-m_t} C_0 q_t
                      + sum_{s<=t} e^{A[t,s]-m_t} (k_s.q_t) v_s
    with F_t = sum_{u<=t} log f_u and A[t,s] = log i_s + F_t - F_s —
    the max commutes through the recurrence, so the chunk-local running
    max is exact, not an approximation.
    """
    B, S, H, hd = q.shape
    chunk = min(chunk, S)
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        zpad = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(a, zpad) for a in (q, k, v))
        # padded sources get -inf input gate (no contribution); padded
        # forget gets 0 so the end-of-chunk carry equals the true final state
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e30)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))

    def resh(a):
        return (a.reshape((B, nc, chunk) + a.shape[2:])
                .swapaxes(0, 1).astype(jnp.float32))

    qc, kc, vc, lic, lfc = map(resh, (q, k, v, log_i, log_f))
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def chunk_step(carry, xs):
        C, n, m = carry             # (B,H,hd,hd), (B,H,hd), (B,H)
        qt, kt, vt, li, lf = xs     # (B,c,H,hd) / (B,c,H)
        F = jnp.cumsum(lf, axis=1)                       # (B,c,H)
        carry_logw = F + m[:, None]                      # (B,c,H)
        A = li[:, None] + F[:, :, None] - F[:, None]     # (B,t,s,H)
        A = jnp.where(tri[None, :, :, None], A, -jnp.inf)
        m_t = jnp.maximum(carry_logw, A.max(axis=2))     # (B,c,H)
        w_carry = jnp.exp(carry_logw - m_t)              # (B,c,H)
        W = jnp.exp(A - m_t[:, :, None])                 # (B,t,s,H)
        W = jnp.where(tri[None, :, :, None], W, 0.0)

        scores = jnp.einsum("bthd,bshd->btsh", qt, kt) * W
        num = (jnp.einsum("btsh,bshd->bthd", scores, vt)
               + w_carry[..., None] * jnp.einsum("bhvk,bthk->bthv", C, qt))
        n_t = (jnp.einsum("btsh,bshd->bthd", W, kt)
               + w_carry[..., None] * n[:, None])
        denom = jnp.maximum(jnp.abs(jnp.einsum("bthd,bthd->bth", n_t, qt)),
                            jnp.exp(-m_t))
        h = num / denom[..., None]

        m_new = m_t[:, -1]
        wl = W[:, -1]                                    # (B,s,H)
        C_new = (w_carry[:, -1][..., None, None] * C
                 + jnp.einsum("bsh,bshv,bshk->bhvk", wl, vt, kt))
        n_new = w_carry[:, -1][..., None] * n + jnp.einsum(
            "bsh,bshk->bhk", wl, kt)
        return (C_new, n_new, m_new), h

    (C, n, m), hs = jax.lax.scan(chunk_step, (C0, n0, m0),
                                 (qc, kc, vc, lic, lfc))
    hs = hs.swapaxes(0, 1).reshape(B, nc * chunk, H, hd)[:, :S]
    return hs, C, n, m


def mlstm_block_apply(params: PyTree, x: Array, cfg, *, mode: str,
                      layer_cache: Optional[PyTree] = None
                      ) -> tuple[Array, Optional[PyTree]]:
    B, S, D = x.shape
    H = cfg.num_heads
    q, k, v, gate, log_i, log_f = _mlstm_qkvg(params, x, cfg)
    hd = q.shape[-1]
    if layer_cache is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.zeros((B, H), jnp.float32)
    else:
        C0, n0, m0 = layer_cache["C"], layer_cache["n"], layer_cache["m"]

    if S == 1:
        hs, C, n, m = mlstm_sequential(q, k, v, log_i, log_f, C0, n0, m0)
    else:
        hs, C, n, m = mlstm_chunked(q, k, v, log_i, log_f, C0, n0, m0)
    hs = hs.reshape(B, S, H * hd).astype(x.dtype)
    hs = rmsnorm(params["out_norm"], hs, cfg.norm_eps) * gate
    out = jnp.einsum("bse,ed->bsd", hs, params["w_down"])
    out = shard(out, ("batch", "seq", "embed"))
    cache = None
    if layer_cache is not None:
        cache = {"C": C, "n": n, "m": m}
    return out, cache


def init_mlstm_cache(cfg, batch: int) -> PyTree:
    H = cfg.num_heads
    hd = _MLSTM_EXP * cfg.d_model // H
    return {"C": jnp.zeros((batch, H, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, H, hd), jnp.float32),
            "m": jnp.zeros((batch, H), jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM block (xLSTM scalar memory, sequential)
# ---------------------------------------------------------------------------
# Per head-channel: c_t = f c_{t-1} + i z;  n_t = f n_{t-1} + i;
# h_t = o * c_t / n_t, with exp input gate (m-stabilized), sigmoid output
# gate, and recurrent weights (block-diag per head) feeding all gates.

_SLSTM_FF = 4 / 3


def slstm_init(key: Array, cfg) -> PyTree:
    d = cfg.d_model
    H = cfg.num_heads
    hd = d // H
    dt = cdtype(cfg)
    ks = jax.random.split(key, 8)
    d_ff = int(_SLSTM_FF * d)
    return {
        "norm": rmsnorm_init(d),
        # input weights for z, i, f, o
        "w_in": dense_init(ks[0], (d, 4 * d), dtype=dt),
        # recurrent weights, block-diagonal per head: (H, hd, 4*hd)
        "w_rec": dense_init(ks[1], (H, hd, 4 * hd), in_axis=1, dtype=dt),
        "b": jnp.concatenate([jnp.zeros((2 * d,)), 3.0 * jnp.ones((d,)),
                              jnp.zeros((d,))]).astype(jnp.float32),
        "out_norm": rmsnorm_init(d),
        # post-FFN (xLSTM sLSTM block, factor 4/3)
        "ff_up": dense_init(ks[2], (d, d_ff), dtype=dt),
        "ff_gate": dense_init(ks[3], (d, d_ff), dtype=dt),
        "ff_down": dense_init(ks[4], (d_ff, d), dtype=dt),
    }


def slstm_apply(params: PyTree, x: Array, cfg, *, mode: str,
                layer_cache: Optional[PyTree] = None
                ) -> tuple[Array, Optional[PyTree]]:
    B, S, D = x.shape
    H = cfg.num_heads
    hd = D // H
    xin = rmsnorm(params["norm"], x, cfg.norm_eps)
    pre = jnp.einsum("bsd,de->bse", xin, params["w_in"]).astype(jnp.float32)
    pre = pre + params["b"]

    if layer_cache is None:
        c0 = jnp.zeros((B, D), jnp.float32)
        n0 = jnp.ones((B, D), jnp.float32)
        m0 = jnp.zeros((B, D), jnp.float32)
        h0 = jnp.zeros((B, D), jnp.float32)
    else:
        c0, n0, m0, h0 = (layer_cache[k] for k in ("c", "n", "m", "h"))

    w_rec = params["w_rec"].astype(jnp.float32)

    def step(carry, pre_t):
        c, n, m, h = carry
        rec = jnp.einsum("bhk,hke->bhe", h.reshape(B, H, hd), w_rec)
        rec = rec.reshape(B, 4 * D)
        zr, ir, fr, orr = jnp.split(pre_t + rec, 4, axis=-1)
        z = jnp.tanh(zr)
        log_i = ir
        log_f = jax.nn.log_sigmoid(fr)
        m_new = jnp.maximum(log_f + m, log_i)
        fa = jnp.exp(log_f + m - m_new)
        ia = jnp.exp(log_i - m_new)
        c_new = fa * c + ia * z
        n_new = fa * n + ia
        h_new = jax.nn.sigmoid(orr) * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, m_new, h_new), h_new

    (c, n, m, h), hs = jax.lax.scan(step, (c0, n0, m0, h0),
                                    pre.swapaxes(0, 1))
    hs = hs.swapaxes(0, 1).astype(x.dtype)  # (B,S,D)
    hs = rmsnorm(params["out_norm"], hs, cfg.norm_eps)
    # block FFN (gated, factor 4/3)
    a = jax.nn.silu(jnp.einsum("bsd,df->bsf", hs, params["ff_gate"]))
    u = jnp.einsum("bsd,df->bsf", hs, params["ff_up"])
    out = jnp.einsum("bsf,fd->bsd", a * u, params["ff_down"])
    out = shard(out, ("batch", "seq", "embed"))
    cache = None
    if layer_cache is not None:
        cache = {"c": c, "n": n, "m": m, "h": h}
    return out, cache


def init_slstm_cache(cfg, batch: int) -> PyTree:
    d = cfg.d_model
    return {"c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.ones((batch, d), jnp.float32),
            "m": jnp.zeros((batch, d), jnp.float32),
            "h": jnp.zeros((batch, d), jnp.float32)}
