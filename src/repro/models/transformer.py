"""Composable transformer model family (all 10 assigned architectures).

A model is built from an `ArchConfig`: the depth-wise `block_pattern`
(attn | swa | rglru | mlstm | slstm) is cycled over `num_layers`;
attention-family blocks get a channel mixer (gated MLP, or MoE when
`num_experts > 0`); xLSTM blocks embed their own mixers (d_ff = 0).
Optional extras per config: cross-attention decoder (audio enc-dec),
token+prefix-embedding inputs (VLM), encoder stack.

Layers are *scanned*: the pattern repeats `num_layers // P` times, so
params/caches carry a leading repetition dim and the HLO contains one
instance of the pattern body regardless of depth (MaxText-style; critical
for 95-layer AOT compiles on one CPU core). Remainder layers (L % P) are
unrolled.

Public API (used by launch/, tests, benchmarks):
    model = Transformer(cfg)
    params = model.init(key)                       # or jax.eval_shape
    logits, aux = model.forward(params, batch)     # train/teacher-forcing
    loss = model.loss(params, batch)
    cache = model.init_cache(batch_size, cache_len)
    logits, cache = model.prefill(params, batch, cache)
    logits, cache = model.decode_step(params, tokens, cache, memory=None)
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, SWA, RGLRU, MLSTM, SLSTM, ArchConfig
from repro.models import layers, moe, recurrent
from repro.models.layers import cdtype
from repro.sharding import shard

Array = jax.Array
PyTree = Any


# ---------------------------------------------------------------------------
# single layer = temporal block (+ cross-attn) (+ channel mixer)
# ---------------------------------------------------------------------------

def _mixer_kind(cfg: ArchConfig, block_kind: str) -> str:
    if block_kind in (MLSTM, SLSTM):
        return "none"
    if cfg.num_experts:
        return "moe"
    return "mlp" if cfg.d_ff else "none"


def layer_init(key: Array, cfg: ArchConfig, block_kind: str,
               cross: bool) -> PyTree:
    k1, k2, k3 = jax.random.split(key, 3)
    p: PyTree = {}
    if block_kind in (ATTN, SWA):
        p["temporal"] = layers.attention_init(k1, cfg)
    elif block_kind == RGLRU:
        p["temporal"] = recurrent.rglru_init(k1, cfg)
    elif block_kind == MLSTM:
        p["temporal"] = recurrent.mlstm_init(k1, cfg)
    elif block_kind == SLSTM:
        p["temporal"] = recurrent.slstm_init(k1, cfg)
    else:
        raise ValueError(block_kind)
    if cross:
        p["cross"] = layers.attention_init(k2, cfg, cross=True)
    mk = _mixer_kind(cfg, block_kind)
    if mk == "mlp":
        p["mlp"] = layers.mlp_init(k3, cfg.d_model, cfg.d_ff, cfg)
    elif mk == "moe":
        p["moe"] = moe.moe_init(k3, cfg)
    return p


def layer_apply(p: PyTree, x: Array, cfg: ArchConfig, block_kind: str, *,
                mode: str, cache: Optional[PyTree],
                memory_kv: Optional[tuple] = None,
                positions: Optional[Array] = None
                ) -> tuple[Array, Optional[PyTree], Array]:
    """Returns (x_out, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: dict = {}
    tcache = None if cache is None else cache.get("temporal")

    if block_kind in (ATTN, SWA):
        window = cfg.window_size if block_kind == SWA else 0
        y, nc = layers.attention_apply(
            p["temporal"], x, cfg, mode=mode, layer_cache=tcache,
            window=window, positions=positions)
    elif block_kind == RGLRU:
        y, nc = recurrent.rglru_apply(p["temporal"], x, cfg, mode=mode,
                                      layer_cache=tcache)
    elif block_kind == MLSTM:
        y, nc = recurrent.mlstm_block_apply(p["temporal"], x, cfg, mode=mode,
                                            layer_cache=tcache)
    elif block_kind == SLSTM:
        y, nc = recurrent.slstm_apply(p["temporal"], x, cfg, mode=mode,
                                      layer_cache=tcache)
    else:
        raise ValueError(block_kind)
    x = x + y
    if nc is not None:
        new_cache["temporal"] = nc

    if "cross" in p and memory_kv is not None:
        y, _ = layers.attention_apply(p["cross"], x, cfg, mode=mode,
                                      memory_kv=memory_kv)
        x = x + y

    mk = _mixer_kind(cfg, block_kind)
    if mk == "mlp":
        x = x + layers.mlp_apply(p["mlp"], x, cfg)
    elif mk == "moe":
        y, aux = moe.moe_apply(p["moe"], x, cfg)
        x = x + y
    return x, (new_cache if new_cache else None), aux


def init_layer_cache(cfg: ArchConfig, block_kind: str, batch: int,
                     cache_len: int, dtype) -> PyTree:
    if block_kind == ATTN:
        return {"temporal": layers.init_attention_cache(
            cfg, batch, cache_len, 0, dtype)}
    if block_kind == SWA:
        return {"temporal": layers.init_attention_cache(
            cfg, batch, cache_len, cfg.window_size, dtype)}
    if block_kind == RGLRU:
        return {"temporal": recurrent.init_rglru_cache(cfg, batch, dtype)}
    if block_kind == MLSTM:
        return {"temporal": recurrent.init_mlstm_cache(cfg, batch)}
    if block_kind == SLSTM:
        return {"temporal": recurrent.init_slstm_cache(cfg, batch)}
    raise ValueError(block_kind)


# ---------------------------------------------------------------------------
# the stack
# ---------------------------------------------------------------------------

class Transformer:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        P = len(cfg.block_pattern)
        self.n_rep = cfg.num_layers // P
        self.n_rem = cfg.num_layers % P
        self.pattern = cfg.block_pattern

    # -- init ---------------------------------------------------------------
    def init(self, key: Array) -> PyTree:
        cfg = self.cfg
        keys = jax.random.split(key, 8)
        params: PyTree = {
            "embed": layers.embedding_init(keys[0], cfg.vocab_size,
                                           cfg.d_model, cdtype(cfg)),
            "final_norm": layers.rmsnorm_init(cfg.d_model),
        }
        cross = cfg.cross_attention

        def group_init(gkey):
            ks = jax.random.split(gkey, len(self.pattern))
            return {f"b{j}": layer_init(ks[j], cfg, kind, cross)
                    for j, kind in enumerate(self.pattern)}

        if self.n_rep:
            params["groups"] = jax.vmap(group_init)(
                jax.random.split(keys[1], self.n_rep))
        for r in range(self.n_rem):
            kind = self.pattern[r]
            params[f"rem{r}"] = layer_init(
                jax.random.fold_in(keys[2], r), cfg, kind, cross)

        if cfg.encoder_layers:
            enc_cfg = cfg
            def enc_layer_init(k):
                return layer_init(k, enc_cfg, ATTN, cross=False)
            params["encoder"] = {
                "layers": jax.vmap(enc_layer_init)(
                    jax.random.split(keys[3], cfg.encoder_layers)),
                "final_norm": layers.rmsnorm_init(cfg.d_model),
            }
        return params

    # -- embedding / inputs ---------------------------------------------------
    def _embed_inputs(self, params: PyTree, batch: dict) -> Array:
        cfg = self.cfg
        if cfg.input_mode == "embeddings":
            x = batch["embeddings"].astype(cdtype(cfg))
        elif cfg.input_mode == "tokens+prefix":
            tok = layers.embed(params["embed"], batch["tokens"])
            prefix = batch["prefix"].astype(tok.dtype)
            prefix = shard(prefix, ("batch", "seq", "embed"))
            x = jnp.concatenate([prefix, tok], axis=1)
        else:
            x = layers.embed(params["embed"], batch["tokens"])
        return shard(x, ("batch", "seq", "embed"))

    # -- encoder --------------------------------------------------------------
    def encode(self, params: PyTree, frames: Array) -> Array:
        """frames: (B, M, d) precomputed frontend embeddings -> memory."""
        cfg = self.cfg
        x = frames.astype(cdtype(cfg))

        def body(x, lp):
            y, _, _ = layer_apply(lp, x, cfg, ATTN, mode="encode", cache=None)
            return y, None

        if cfg.remat:  # same per-layer checkpointing as the decoder stack
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(body, x, params["encoder"]["layers"])
        return layers.rmsnorm(params["encoder"]["final_norm"], x,
                              cfg.norm_eps)

    def _memory_kv(self, params_attn: PyTree, memory: Array
                   ) -> tuple[Array, Array]:
        """Precompute cross-attention K/V from encoder memory."""
        h = layers.rmsnorm(params_attn["norm"], memory, self.cfg.norm_eps)
        k = jnp.einsum("bsd,dhk->bshk", h, params_attn["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, params_attn["wv"])
        return k, v

    # -- full-sequence forward (train / teacher forcing) ----------------------
    def forward(self, params: PyTree, batch: dict,
                mode: str = "train") -> tuple[Array, Array]:
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        memory = None
        if cfg.encoder_layers:
            memory = self.encode(params, batch["frames"])

        aux_total = jnp.zeros((), jnp.float32)

        def apply_group(x, gparams, aux):
            for j, kind in enumerate(self.pattern):
                mkv = None
                if "cross" in gparams[f"b{j}"] and memory is not None:
                    mkv = self._memory_kv(gparams[f"b{j}"]["cross"], memory)
                x, _, a = layer_apply(gparams[f"b{j}"], x, cfg, kind,
                                      mode=mode, cache=None, memory_kv=mkv)
                aux = aux + a
            return x, aux

        if self.n_rep:
            def body(carry, gparams):
                x, aux = carry
                x, aux = apply_group(x, gparams, aux)
                # sequence-parallel residual boundary: the remat-saved
                # carry stack shards its seq dim over "model" (rules map
                # residual_seq -> model at train), cutting the dominant
                # train-time buffer by the TP degree; GSPMD re-gathers
                # K/V inside the layer where full seq is needed
                x = shard(x, ("batch", "residual_seq", "embed"))
                return (x, aux), None
            if cfg.remat:
                # per-group activation checkpointing: backward recomputes
                # the group from its (B,S,D) input; without this the scan
                # stacks every attention/MLP intermediate for the bwd pass
                # (hundreds of GiB/device at train_4k - see EXPERIMENTS.md
                # §Perf iteration 1)
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.nothing_saveable)
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total),
                                             params["groups"])
        # remainder layers (unrolled, single layer each)
        for r in range(self.n_rem):
            kind = self.pattern[r]
            mkv = None
            if "cross" in params[f"rem{r}"] and memory is not None:
                mkv = self._memory_kv(params[f"rem{r}"]["cross"], memory)
            x, _, a = layer_apply(params[f"rem{r}"], x, cfg, kind, mode=mode,
                                  cache=None, memory_kv=mkv)
            aux_total = aux_total + a

        x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = layers.unembed(params["embed"], x)
        return logits, aux_total

    # -- loss ------------------------------------------------------------------
    def loss(self, params: PyTree, batch: dict,
             aux_weight: float = 0.01) -> Array:
        """Next-token cross-entropy (+ MoE aux). batch["labels"]: (B, S)
        with -1 = masked."""
        logits, aux = self.forward(params, batch)
        labels = batch["labels"]
        if self.cfg.input_mode == "tokens+prefix":
            logits = logits[:, self.cfg.prefix_len:]
        logits = logits[:, :-1]
        targets = labels[:, 1:]
        mask = targets >= 0
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(lp, jnp.maximum(targets, 0)[..., None],
                                 axis=-1)[..., 0]
        ce = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1)
        return ce + aux_weight * aux

    # -- caches ------------------------------------------------------------------
    def init_cache(self, batch: int, cache_len: int,
                   memory: Optional[Array] = None,
                   params: Optional[PyTree] = None) -> PyTree:
        cfg = self.cfg
        dt = cdtype(cfg)
        cache: PyTree = {}

        def one(kind):
            return init_layer_cache(cfg, kind, batch, cache_len, dt)

        if self.n_rep:
            def group_cache(_):
                return {f"b{j}": one(kind)
                        for j, kind in enumerate(self.pattern)}
            cache["groups"] = jax.vmap(group_cache)(jnp.arange(self.n_rep))
        for r in range(self.n_rem):
            cache[f"rem{r}"] = one(self.pattern[r])

        if cfg.cross_attention and memory is not None and params is not None:
            # precompute cross K/V per decoder layer (prefill-time)
            if self.n_rep:
                cache["cross_kv"] = jax.vmap(
                    lambda gp: {f"b{j}": jnp.stack(self._memory_kv(
                        gp[f"b{j}"]["cross"], memory))
                        for j in range(len(self.pattern))}
                )(params["groups"])
            for r in range(self.n_rem):
                cache[f"cross_kv_rem{r}"] = jnp.stack(self._memory_kv(
                    params[f"rem{r}"]["cross"], memory))
        return cache

    # -- prefill / decode ----------------------------------------------------
    def _run_with_cache(self, params: PyTree, x: Array, cache: PyTree,
                        mode: str) -> tuple[Array, PyTree]:
        cfg = self.cfg

        def apply_one(x, lp, lc, kind, cross_kv):
            mkv = None
            if cross_kv is not None:
                mkv = (cross_kv[0], cross_kv[1])
            return layer_apply(lp, x, cfg, kind, mode=mode, cache=lc,
                               memory_kv=mkv)

        new_cache: PyTree = {}
        if self.n_rep:
            has_cross = "cross_kv" in cache

            def body(x, xs):
                gp, gc, ckv = xs
                ncs = {}
                for j, kind in enumerate(self.pattern):
                    mkv = ckv[f"b{j}"] if ckv is not None else None
                    x, nc, _ = apply_one(x, gp[f"b{j}"], gc[f"b{j}"], kind,
                                         mkv)
                    ncs[f"b{j}"] = nc
                return x, ncs

            xs = (params["groups"], cache["groups"],
                  cache["cross_kv"] if has_cross else None)
            if has_cross:
                x, gcache = jax.lax.scan(body, x, xs)
            else:
                def body2(x, xs2):
                    gp, gc = xs2
                    return body(x, (gp, gc, None))
                x, gcache = jax.lax.scan(body2, x,
                                         (params["groups"], cache["groups"]))
            new_cache["groups"] = gcache
            if has_cross:
                new_cache["cross_kv"] = cache["cross_kv"]
        for r in range(self.n_rem):
            kind = self.pattern[r]
            ckv = cache.get(f"cross_kv_rem{r}")
            x, nc, _ = apply_one(x, params[f"rem{r}"], cache[f"rem{r}"],
                                 kind, ckv)
            new_cache[f"rem{r}"] = nc
            if ckv is not None:
                new_cache[f"cross_kv_rem{r}"] = ckv

        x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return x, new_cache

    def prefill(self, params: PyTree, batch: dict,
                cache: PyTree) -> tuple[Array, PyTree]:
        """Run the prompt through the model, filling the cache. Returns
        (last-position logits, cache)."""
        x = self._embed_inputs(params, batch)
        x, cache = self._run_with_cache(params, x, cache, "prefill")
        logits = layers.unembed(params["embed"], x[:, -1:])
        return logits, cache

    def decode_step(self, params: PyTree, tokens: Array,
                    cache: PyTree) -> tuple[Array, PyTree]:
        """tokens: (B, 1). Returns (logits (B,1,V), new cache)."""
        x = layers.embed(params["embed"], tokens)
        x = shard(x, ("batch", "seq", "embed"))
        x, cache = self._run_with_cache(params, x, cache, "decode")
        logits = layers.unembed(params["embed"], x)
        return logits, cache
