"""repro.obs — structured telemetry bus.

Typed events (events), composable sinks + stream readers (sinks),
per-stage span tracing and jax.profiler windows (trace), and the
terminal run monitor (monitor). See docs/obs.md for the event schema.
"""
from repro.obs.events import (EVENT_SCHEMA, EVENT_TYPES, Emitter, Event,
                              KernelEvent, LogEvent, NULL, NullEmitter,
                              RoundEvent, RunClock, RunEnd, RunStart,
                              StageEvent, SweepEvent, new_run_id, parse,
                              parse_line)
from repro.obs.sinks import (CsvSink, FanoutSink, JsonlSink,
                             RingBufferSink, Sink, default_obs_dir,
                             follow_jsonl, merge_streams, read_events)
from repro.obs.trace import (RoundProfiler, StageTracer, activated,
                             current, install, note_kernel, stage_span,
                             uninstall)

__all__ = [
    "EVENT_SCHEMA", "EVENT_TYPES", "Emitter", "Event", "KernelEvent",
    "LogEvent", "NULL", "NullEmitter", "RoundEvent", "RunClock",
    "RunEnd", "RunStart", "StageEvent", "SweepEvent", "new_run_id",
    "parse", "parse_line",
    "CsvSink", "FanoutSink", "JsonlSink", "RingBufferSink", "Sink",
    "default_obs_dir", "follow_jsonl", "merge_streams", "read_events",
    "RoundProfiler", "StageTracer", "activated", "current", "install",
    "note_kernel", "stage_span", "uninstall",
]
