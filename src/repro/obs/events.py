"""Typed telemetry events + the run emitter (the obs bus's data model).

Every observable moment of a run is one typed event on a JSONL stream:

  RunStart    run identity (run_id / scenario / seed / engine), fleet
              shape, the full ExperimentSpec that produced the run
  RoundEvent  one communication round's metric row — the same floats
              that land in the artifact history, bit-equal (the runner
              builds one row dict and feeds both)
  StageEvent  a span: host-side wall-time of one pipeline stage
              (phase="host" for per-round driver phases, phase="trace"
              for RoundPipeline stages timed during jit tracing)
  KernelEvent a kernel dispatch decision (pallas vs interpret/ref)
  SweepEvent  one finished (scenario, seed) cell of a sweep/benchmark
  LogEvent    the human-readable progress line, preserved in-stream
  RunEnd      terminal summary (rounds completed, cumulative totals)

Events carry a monotonic run clock `t_s` (seconds since the emitter was
created, `time.perf_counter` based — immune to wall-clock steps) plus
the `run_id` so streams from different processes (sweep pools write one
stream per worker process) can be merged and re-grouped by run.

`Emitter` stamps identity + clock onto events and forwards to a sink
(`repro.obs.sinks`). `NULL` is the disabled emitter: every method is a
no-op (spans return a shared nullcontext), so obs-off runs pay only a
few attribute checks per round.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import time
import uuid
from typing import Any, ClassVar, Iterator, Optional

EVENT_SCHEMA = 1


class RunClock:
    """Monotonic seconds since construction (the run's t=0)."""

    def __init__(self) -> None:
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0


def new_run_id(tag: str) -> str:
    """Collision-safe id: <tag>__<utc stamp>__p<pid>__<nonce>. The tag
    (scenario name / seed) keeps streams human-greppable; pid + nonce
    keep `sweep(jobs=N)` pool processes from colliding."""
    safe = tag.replace("/", "-").replace(" ", "_") or "run"
    stamp = time.strftime("%Y%m%dT%H%M%S", time.gmtime())
    return f"{safe}__{stamp}__p{os.getpid()}__{uuid.uuid4().hex[:6]}"


# ---------------------------------------------------------------------------
# event types
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Event:
    """Base: identity + run clock. Subclasses set `kind`."""
    kind: ClassVar[str] = ""
    run_id: str = ""
    t_s: float = 0.0

    def to_dict(self) -> dict:
        d = {"kind": self.kind}
        d.update(dataclasses.asdict(self))
        return d

    def to_json(self) -> str:
        return json.dumps(self.to_dict())


@dataclasses.dataclass(frozen=True)
class RunStart(Event):
    kind: ClassVar[str] = "run_start"
    scenario: str = ""
    seed: int = 0
    engine: str = ""                 # "paper" | "mesh"
    num_workers: int = 0
    rounds: int = 0
    n_params: int = 0
    population: int = 0              # registered fleet size (0 = no
    #                                  population engine: full fleet)
    cohort: int = 0                  # active devices per round (0 = all)
    schema: int = EVENT_SCHEMA
    wall_time: float = 0.0           # unix epoch at start (for humans)
    spec: Optional[dict] = None      # full ExperimentSpec (to_dict)


@dataclasses.dataclass(frozen=True)
class RoundEvent(Event):
    """One communication round's metrics row, bit-equal to the artifact
    history (experiments/runner.py builds both from the same dict).

    `metrics` is free-form on purpose — engine features surface new
    keys without an event-schema bump. Stable keys: acc/global_loss,
    selected/delivered, bytes_up/bytes_down, airtime_s/energy_j,
    mean_snr_db. The straggler engine (comm.straggler) adds
    late/drained/buffered/held, fault injection adds transmitted, and
    the population engine adds the cohort id list — each present only
    when its feature is on, so stream consumers key off membership."""
    kind: ClassVar[str] = "round"
    round: int = 0                   # 0-based round index
    metrics: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class StageEvent(Event):
    kind: ClassVar[str] = "stage"
    stage: str = ""                  # LocalUpdate/ScoreSelect/... or Step/Eval
    dur_s: float = 0.0
    phase: str = "host"              # "host" | "trace"
    round: Optional[int] = None      # None for trace-time spans


@dataclasses.dataclass(frozen=True)
class KernelEvent(Event):
    kind: ClassVar[str] = "kernel"
    name: str = ""                   # e.g. "quant_pack"
    backend: str = ""                # jax.default_backend()
    interpret: bool = False          # ref/interpret fallback vs compiled
    info: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class SweepEvent(Event):
    kind: ClassVar[str] = "sweep"
    cell: str = ""                   # scenario name / benchmark cell label
    seed: int = 0
    status: str = "ok"
    final: Optional[float] = None    # headline metric (acc or loss)
    wall_s: Optional[float] = None
    artifact: Optional[str] = None   # metrics JSON path
    events: Optional[str] = None     # the cell's own event stream
    metrics: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class LogEvent(Event):
    kind: ClassVar[str] = "log"
    msg: str = ""


@dataclasses.dataclass(frozen=True)
class RunEnd(Event):
    kind: ClassVar[str] = "run_end"
    rounds: int = 0
    status: str = "ok"
    totals: dict = dataclasses.field(default_factory=dict)


EVENT_TYPES: dict[str, type] = {
    c.kind: c for c in (RunStart, RoundEvent, StageEvent, KernelEvent,
                        SweepEvent, LogEvent, RunEnd)
}


def parse(obj: dict) -> Event:
    """dict (one decoded JSONL line) -> typed event. Unknown kinds and
    unknown fields fail loudly — a stream a newer writer produced should
    be read with that writer's schema, not silently mangled."""
    d = dict(obj)
    kind = d.pop("kind", None)
    cls = EVENT_TYPES.get(kind)
    if cls is None:
        raise ValueError(f"unknown event kind {kind!r} "
                         f"(known: {sorted(EVENT_TYPES)})")
    names = {f.name for f in dataclasses.fields(cls)}
    unknown = set(d) - names
    if unknown:
        raise ValueError(f"unknown {cls.__name__} fields: {sorted(unknown)}")
    return cls(**d)


def parse_line(line: str) -> Event:
    return parse(json.loads(line))


# ---------------------------------------------------------------------------
# the emitter
# ---------------------------------------------------------------------------

_NULLCTX = contextlib.nullcontext()


class Emitter:
    """Stamps run identity + the monotonic clock onto events and feeds
    a sink. One emitter == one run == one stream."""

    active = True

    def __init__(self, run_id: str, sink: Any, clock: RunClock = None):
        self.run_id = run_id
        self.sink = sink
        self.clock = clock or RunClock()

    @property
    def path(self) -> Optional[str]:
        p = getattr(self.sink, "path", None)
        return str(p) if p is not None else None

    def emit(self, event: Event) -> None:
        self.sink.emit(event)

    def _stamp(self, cls, **kw) -> Event:
        ev = cls(run_id=self.run_id, t_s=self.clock.now(), **kw)
        self.emit(ev)
        return ev

    # -- typed helpers ---------------------------------------------------
    def run_start(self, **kw) -> Event:
        return self._stamp(RunStart, wall_time=time.time(), **kw)

    def round(self, round_idx: int, metrics: dict) -> Event:
        return self._stamp(RoundEvent, round=round_idx, metrics=metrics)

    def stage(self, stage: str, dur_s: float, *, phase: str = "host",
              round_idx: Optional[int] = None) -> Event:
        return self._stamp(StageEvent, stage=stage, dur_s=dur_s,
                           phase=phase, round=round_idx)

    def kernel(self, name: str, *, backend: str, interpret: bool,
               **info) -> Event:
        return self._stamp(KernelEvent, name=name, backend=backend,
                           interpret=interpret, info=info)

    def sweep_cell(self, cell: str, **kw) -> Event:
        return self._stamp(SweepEvent, cell=cell, **kw)

    def run_end(self, rounds: int, totals: dict = None,
                status: str = "ok") -> Event:
        return self._stamp(RunEnd, rounds=rounds, totals=totals or {},
                           status=status)

    def log(self, msg: str, echo: bool = True) -> None:
        """The human progress line: printed (when echoed) AND kept on
        the stream, so a finished run's transcript replays in the
        monitor."""
        if echo:
            print(msg, flush=True)
        self._stamp(LogEvent, msg=msg)

    @contextlib.contextmanager
    def span(self, stage: str, *, round_idx: Optional[int] = None,
             phase: str = "host") -> Iterator[None]:
        t0 = self.clock.now()
        try:
            yield
        finally:
            self.stage(stage, self.clock.now() - t0, phase=phase,
                       round_idx=round_idx)

    def flush(self) -> None:
        self.sink.flush()

    def close(self) -> None:
        self.sink.close()


class NullEmitter:
    """Obs disabled: every hook is a no-op; `log` still echoes so the
    verbose path prints exactly as before."""

    active = False
    run_id = ""
    path = None

    def emit(self, event: Event) -> None:
        pass

    def run_start(self, **kw) -> None:
        pass

    def round(self, round_idx: int, metrics: dict) -> None:
        pass

    def stage(self, *a, **kw) -> None:
        pass

    def kernel(self, *a, **kw) -> None:
        pass

    def sweep_cell(self, *a, **kw) -> None:
        pass

    def run_end(self, *a, **kw) -> None:
        pass

    def log(self, msg: str, echo: bool = True) -> None:
        if echo:
            print(msg, flush=True)

    def span(self, stage: str, *, round_idx: Optional[int] = None,
             phase: str = "host"):
        return _NULLCTX

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


NULL = NullEmitter()
