"""Terminal dashboard over a repro.obs event stream.

    python -m repro.obs.monitor artifacts/obs/quickstart__...jsonl
    python -m repro.obs.monitor artifacts/obs/ --follow
    python -m repro.launch.monitor <run.jsonl> --follow   # same tool

Renders, for a finished stream or a live tail (--follow): run identity
and round progress, round rate, global loss / accuracy trajectories
(sparklines), selection and delivery counts, cumulative bytes / airtime
/ energy, and the per-stage time breakdown (host phases per round +
trace-time pipeline stages). Sweep streams render as a per-cell table.
Pure stdlib — it must work over ssh on the edge gateway the run lives
on.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
from pathlib import Path
from typing import Iterable, Optional

from repro.obs.events import (Event, KernelEvent, LogEvent, RoundEvent,
                              RunEnd, RunStart, StageEvent, SweepEvent)
from repro.obs.sinks import follow_jsonl, read_events

SPARK = "▁▂▃▄▅▆▇█"  # ▁..█


def spark(values: list[float], width: int = 40) -> str:
    """Unicode sparkline, downsampled to `width` buckets."""
    vals = [float(v) for v in values if v == v]  # drop NaN
    if not vals:
        return ""
    if len(vals) > width:
        step = len(vals) / width
        vals = [vals[int(i * step)] for i in range(width)]
    lo, hi = min(vals), max(vals)
    span = (hi - lo) or 1.0
    return "".join(SPARK[int((v - lo) / span * (len(SPARK) - 1))]
                   for v in vals)


def _fmt_bytes(n: float) -> str:
    for unit, div in (("GiB", 2**30), ("MiB", 2**20), ("KiB", 2**10)):
        if abs(n) >= div:
            return f"{n / div:.2f}{unit}"
    return f"{n:.0f}B"


@dataclasses.dataclass
class RunView:
    """Everything the renderer needs, folded from one run's events."""
    start: Optional[RunStart] = None
    rounds: list[RoundEvent] = dataclasses.field(default_factory=list)
    stages: dict = dataclasses.field(default_factory=dict)
    kernels: list[KernelEvent] = dataclasses.field(default_factory=list)
    cells: list[SweepEvent] = dataclasses.field(default_factory=list)
    logs: list[LogEvent] = dataclasses.field(default_factory=list)
    end: Optional[RunEnd] = None

    def metric(self, key: str) -> list[float]:
        return [e.metrics[key] for e in self.rounds if key in e.metrics]


def summarize(events: Iterable[Event]) -> RunView:
    v = RunView()
    for ev in events:
        if isinstance(ev, RunStart):
            v.start = ev
        elif isinstance(ev, RoundEvent):
            v.rounds.append(ev)
        elif isinstance(ev, StageEvent):
            cnt, tot = v.stages.get((ev.phase, ev.stage), (0, 0.0))
            v.stages[(ev.phase, ev.stage)] = (cnt + 1, tot + ev.dur_s)
        elif isinstance(ev, KernelEvent):
            v.kernels.append(ev)
        elif isinstance(ev, SweepEvent):
            v.cells.append(ev)
        elif isinstance(ev, LogEvent):
            v.logs.append(ev)
        elif isinstance(ev, RunEnd):
            v.end = ev
    return v


def _trajectory_lines(v: RunView, width: int) -> list[str]:
    out = []
    for key, label in (("global_loss", "loss"), ("acc", "acc ")):
        ys = v.metric(key)
        if ys:
            out.append(f"  {label}  {ys[0]:.4f} -> {ys[-1]:.4f}  "
                       f"{spark(ys, width - 30)}")
    return out


def _stage_lines(v: RunView) -> list[str]:
    out = []
    for phase, title in (("host", "stages (host, per round)"),
                         ("trace", "stages (jit trace)")):
        rows = [(s, c, t) for (p, s), (c, t) in sorted(v.stages.items())
                if p == phase]
        if not rows:
            continue
        total = sum(t for _, _, t in rows) or 1.0
        out.append(f"  {title}:")
        for stage, cnt, tot in sorted(rows, key=lambda r: -r[2]):
            bar = "#" * max(1, int(20 * tot / total))
            out.append(f"    {stage:<12} {cnt:>4}x  total {tot:8.3f}s  "
                       f"avg {tot / cnt:8.4f}s  {bar}")
    return out


def _sweep_lines(v: RunView) -> list[str]:
    out = [f"  cells ({len(v.cells)}):"]
    for c in v.cells:
        final = "-" if c.final is None else f"{c.final:.4f}"
        wall = "-" if c.wall_s is None else f"{c.wall_s:.1f}s"
        extra = ""
        if "total_energy_j" in c.metrics:
            extra = f"  energy={c.metrics['total_energy_j']:.3f}J"
        out.append(f"    {c.cell:<28} s{c.seed}  final={final:<8} "
                   f"wall={wall:<7}{extra}")
    return out


def render(events: Iterable[Event], width: int = 78) -> str:
    """One full dashboard frame as a string (stateless: re-renders from
    the event list every time, so --follow is just re-render on tail)."""
    v = summarize(events)
    lines: list[str] = []
    s = v.start
    if s is not None:
        total = f"/{s.rounds}" if s.rounds else ""
        lines.append(f"run {s.scenario or s.run_id} s{s.seed} "
                     f"[{s.engine}] C={s.num_workers} "
                     f"n_params={s.n_params}")
        done = len(v.rounds)
        t_last = v.rounds[-1].t_s if v.rounds else 0.0
        rate = done / t_last if t_last > 0 else 0.0
        state = "done" if v.end is not None else "running"
        lines.append(f"  rounds {done}{total}  {state}  "
                     f"{t_last:.1f}s elapsed  {rate:.2f} rounds/s")
    elif not v.cells:
        lines.append("(no run_start event yet)")

    lines += _trajectory_lines(v, width)

    if v.rounds:
        last = v.rounds[-1].metrics
        sel = v.metric("selected")
        del_ = v.metric("delivered")
        if sel:
            dropped = (f"  dropped(last)="
                       f"{last.get('selected', 0) - last.get('delivered', 0):g}"
                       if del_ else "")
            lines.append(f"  selected last={last.get('selected', 0):g} "
                         f"mean={sum(sel) / len(sel):.1f}"
                         + (f"  delivered mean={sum(del_) / len(del_):.1f}"
                            if del_ else "") + dropped)
        up, down = sum(v.metric("bytes_up")), sum(v.metric("bytes_down"))
        air, en = sum(v.metric("airtime_s")), sum(v.metric("energy_j"))
        lines.append(f"  bytes up={_fmt_bytes(up)} down={_fmt_bytes(down)}"
                     f"  airtime={air:.3f}s  energy={en:.3f}J")

    lines += _stage_lines(v)

    if v.kernels:
        ks = {(k.name, k.backend, k.interpret) for k in v.kernels}
        lines.append("  kernels: " + ", ".join(
            f"{n}[{'interpret' if i else 'compiled'}@{b}]"
            for n, b, i in sorted(ks)))

    if v.cells:
        lines += _sweep_lines(v)

    if v.end is not None:
        tot = "  ".join(f"{k}={v.end.totals[k]:.4g}"
                        for k in sorted(v.end.totals))
        lines.append(f"  end: status={v.end.status} "
                     f"rounds={v.end.rounds}  {tot}")
    return "\n".join(line[:width] for line in lines)


def resolve_stream(path: str | Path) -> Path:
    """A file is itself; a directory means its newest *.jsonl stream."""
    p = Path(path)
    if p.is_dir():
        streams = sorted(p.glob("*.jsonl"), key=lambda f: f.stat().st_mtime)
        if not streams:
            raise FileNotFoundError(f"no *.jsonl streams under {p}")
        return streams[-1]
    if not p.exists():
        raise FileNotFoundError(str(p))
    return p


def follow(path: Path, width: int, interval_s: float,
           out=sys.stdout) -> None:
    """Re-render the dashboard as the stream grows; returns after the
    run_end event lands (or Ctrl-C)."""
    events: list[Event] = []
    try:
        for ev in follow_jsonl(path, poll_s=interval_s):
            events.append(ev)
            if isinstance(ev, (RoundEvent, RunEnd, RunStart, SweepEvent)):
                out.write("\x1b[2J\x1b[H" + render(events, width) + "\n")
                out.flush()
    except KeyboardInterrupt:
        pass


def main(argv: Optional[list[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        description="Render a repro.obs event stream (file, or a "
                    "directory meaning its newest stream).")
    ap.add_argument("stream", help="run .jsonl path or obs directory")
    ap.add_argument("--follow", action="store_true",
                    help="tail a live run, re-rendering per round")
    ap.add_argument("--interval", type=float, default=0.5,
                    help="poll interval for --follow (seconds)")
    ap.add_argument("--width", type=int, default=100)
    args = ap.parse_args(argv)
    path = resolve_stream(args.stream)
    try:
        if args.follow:
            follow(path, args.width, args.interval)
            return
        print(render(read_events(path), args.width))
    except BrokenPipeError:  # e.g. `monitor ... | head`
        sys.stderr.close()


if __name__ == "__main__":
    main()
