"""Composable event sinks + stream readers.

Write side: `JsonlSink` (the canonical append stream under
`artifacts/obs/`, one line per event, flushed per emit so `monitor.py
--follow` tails a live run), `CsvSink` (per-round metric rows for
spreadsheet folks), `RingBufferSink` (in-memory tail for tests and
embedders), `FanoutSink` (tee). All sinks are process-local: under
`sweep(jobs=N)` every pool process writes its own stream file (run ids
embed the pid), and `merge_streams` re-groups a directory of streams by
run id on the read side — no cross-process file locking anywhere.

Read side: `read_events` (strict typed parse), `follow_jsonl`
(tail -f semantics with rotation awareness), `merge_streams`.
"""
from __future__ import annotations

import csv
import json
import time
from pathlib import Path
from typing import Iterable, Iterator, Optional

from repro.obs.events import Event, RoundEvent, parse_line

# repo root: src/repro/obs/sinks.py -> parents[3]
OBS_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "obs"


def default_obs_dir() -> Path:
    return OBS_DIR


class Sink:
    """Interface: emit/flush/close (context-manager sugar included)."""

    def emit(self, event: Event) -> None:
        raise NotImplementedError

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass

    def __enter__(self) -> "Sink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class JsonlSink(Sink):
    """Append-only JSONL stream, flushed per event (a round is a slow
    beat — durability and tailability beat buffering). `rotate_bytes`
    caps the live file: on overflow the current file shifts to
    `<name>.1` and a fresh stream continues (long sweeps can't fill the
    disk with one unbounded file)."""

    def __init__(self, path: str | Path, rotate_bytes: int = 0):
        self.path = Path(path)
        self.rotate_bytes = rotate_bytes
        self._fh = None

    def _open(self):
        if self._fh is None:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self._fh = self.path.open("a")
        return self._fh

    def emit(self, event: Event) -> None:
        fh = self._open()
        fh.write(event.to_json() + "\n")
        fh.flush()
        if self.rotate_bytes and fh.tell() > self.rotate_bytes:
            self._rotate()

    def _rotate(self) -> None:
        self._fh.close()
        self._fh = None
        self.path.replace(self.path.with_name(self.path.name + ".1"))

    def flush(self) -> None:
        if self._fh is not None:
            self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


class CsvSink(Sink):
    """Per-round metric rows as CSV. Columns are fixed by the first
    RoundEvent (run_id, round, t_s, then the row's metric keys in
    insertion order); later events write those columns, missing keys
    empty. Non-round events are ignored — CSV is the spreadsheet view,
    the JSONL stream stays the source of truth."""

    def __init__(self, path: str | Path):
        self.path = Path(path)
        self._fh = None
        self._writer = None
        self._fields: Optional[list[str]] = None

    def emit(self, event: Event) -> None:
        if not isinstance(event, RoundEvent):
            return
        if self._writer is None:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self._fh = self.path.open("w", newline="")
            self._fields = (["run_id", "round", "t_s"]
                            + list(event.metrics))
            self._writer = csv.DictWriter(self._fh, self._fields,
                                          extrasaction="ignore")
            self._writer.writeheader()
        row = {"run_id": event.run_id, "round": event.round,
               "t_s": event.t_s}
        row.update(event.metrics)
        self._writer.writerow(row)
        self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


class RingBufferSink(Sink):
    """Last-N events in memory (tests, embedded dashboards)."""

    def __init__(self, capacity: int = 4096):
        self.capacity = capacity
        self.events: list[Event] = []

    def emit(self, event: Event) -> None:
        self.events.append(event)
        if len(self.events) > self.capacity:
            del self.events[: len(self.events) - self.capacity]


class FanoutSink(Sink):
    """Tee one emitter into several sinks (JSONL + CSV + ring...).
    `path` proxies the first path-bearing child so Emitter.path still
    names the canonical stream."""

    def __init__(self, *sinks: Sink):
        self.sinks = sinks

    @property
    def path(self):
        for s in self.sinks:
            p = getattr(s, "path", None)
            if p is not None:
                return p
        return None

    def emit(self, event: Event) -> None:
        for s in self.sinks:
            s.emit(event)

    def flush(self) -> None:
        for s in self.sinks:
            s.flush()

    def close(self) -> None:
        for s in self.sinks:
            s.close()


# ---------------------------------------------------------------------------
# read side
# ---------------------------------------------------------------------------

def iter_jsonl(path: str | Path) -> Iterator[dict]:
    with Path(path).open() as fh:
        for line in fh:
            line = line.strip()
            if line:
                yield json.loads(line)


def read_events(path: str | Path) -> list[Event]:
    """Strict typed parse of one stream (unknown kinds/fields raise)."""
    return [parse_line(json.dumps(d)) for d in iter_jsonl(path)]


def follow_jsonl(path: str | Path, poll_s: float = 0.5,
                 stop_kinds: tuple[str, ...] = ("run_end",),
                 timeout_s: Optional[float] = None) -> Iterator[Event]:
    """tail -f one stream: yields events as the producer appends them,
    returning after a `stop_kinds` event (the run is over) or after
    `timeout_s` with no growth. Ctrl-C is the other exit."""
    path = Path(path)
    pos = 0
    deadline = None if timeout_s is None else time.time() + timeout_s
    while True:
        if path.exists():
            with path.open() as fh:
                fh.seek(pos)
                while True:
                    # readline (not iteration) keeps fh.tell() legal
                    line = fh.readline()
                    if not line or not line.endswith("\n"):
                        break  # EOF or partial write: re-read next poll
                    pos = fh.tell()
                    line = line.strip()
                    if not line:
                        continue
                    ev = parse_line(line)
                    yield ev
                    deadline = (None if timeout_s is None
                                else time.time() + timeout_s)
                    if ev.kind in stop_kinds:
                        return
        if deadline is not None and time.time() > deadline:
            return
        time.sleep(poll_s)


def merge_streams(paths: Iterable[str | Path]
                  ) -> dict[str, list[Event]]:
    """Re-group many per-process stream files by run id, each run's
    events ordered by its monotonic clock (the sweep-pool merge)."""
    runs: dict[str, list[Event]] = {}
    for p in paths:
        for ev in read_events(p):
            runs.setdefault(ev.run_id, []).append(ev)
    for evs in runs.values():
        evs.sort(key=lambda e: e.t_s)
    return runs
