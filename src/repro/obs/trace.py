"""Per-stage span tracing + jax.profiler integration.

`stage_span(name)` is the single instrumentation point the round
pipeline and both engines call around their stages (LocalUpdate /
ScoreSelect / Uplink / Aggregate / Downlink / BestTracking). With no
tracer installed it returns a shared `nullcontext` — one module-global
load and an identity context manager, so the disabled path adds no
measurable work and, critically, no host sync inside jit.

With a `StageTracer` installed (the runner does this for obs-enabled
runs, BEFORE the first step so the spans fire during the round-0 jit
trace), each span:

  * records host-side wall-time and emits a StageEvent. Stages inside a
    jitted round body execute once, at trace time — those spans are
    tagged phase="trace" (per-stage tracing/compile cost breakdown);
    per-round steady-state timings come from the runner's phase="host"
    spans (Step = dispatch + device sync, Eval = accuracy fetch).
  * enters `jax.named_scope(name)`, so device-side profiler traces
    (`--profile-dir`) carry the stage names into TensorBoard.

`RoundProfiler` owns the `jax.profiler.start_trace`/`stop_trace` window
(`--profile-dir` captures `profile_rounds` rounds starting after the
round-0 compile) and wraps each captured round in a
`StepTraceAnnotation`, the marker TensorBoard's step view groups by.

`note_kernel` is the KernelEvent hook kernels call at dispatch-decision
time (pallas vs interpret/ref) — see `repro.kernels.runtime`.
"""
from __future__ import annotations

import contextlib
import time
from typing import Iterator, Optional

import jax

from repro.obs.events import Emitter

_NOOP = contextlib.nullcontext()
_ACTIVE: Optional["StageTracer"] = None


class StageTracer:
    """Emits StageEvents for `stage_span` blocks while installed."""

    def __init__(self, emitter: Emitter, phase: str = "trace"):
        self.emitter = emitter
        self.phase = phase

    @contextlib.contextmanager
    def span(self, stage: str) -> Iterator[None]:
        t0 = time.perf_counter()
        with jax.named_scope(stage):
            try:
                yield
            finally:
                self.emitter.stage(stage, time.perf_counter() - t0,
                                   phase=self.phase)

    def kernel(self, name: str, *, backend: str, interpret: bool,
               **info) -> None:
        self.emitter.kernel(name, backend=backend, interpret=interpret,
                            **info)


def install(tracer: StageTracer) -> None:
    global _ACTIVE
    _ACTIVE = tracer


def uninstall() -> None:
    global _ACTIVE
    _ACTIVE = None


def current() -> Optional[StageTracer]:
    return _ACTIVE


@contextlib.contextmanager
def activated(tracer: Optional[StageTracer]) -> Iterator[None]:
    """Install `tracer` for the duration (None = leave as-is)."""
    if tracer is None:
        yield
        return
    prev = _ACTIVE
    install(tracer)
    try:
        yield
    finally:
        install(prev) if prev is not None else uninstall()


def stage_span(name: str):
    """The pipeline/engine instrumentation point. No tracer -> a shared
    nullcontext (near-zero disabled overhead, nothing added inside
    jit); tracer -> timed span + jax.named_scope."""
    t = _ACTIVE
    if t is None:
        return _NOOP
    return t.span(name)


def note_kernel(name: str, *, backend: str, interpret: bool,
                **info) -> None:
    """Kernel dispatch hook: emits a KernelEvent when tracing is on."""
    t = _ACTIVE
    if t is not None:
        t.kernel(name, backend=backend, interpret=interpret, **info)


# ---------------------------------------------------------------------------
# jax.profiler round windows
# ---------------------------------------------------------------------------

class RoundProfiler:
    """Capture a TensorBoard-loadable device trace for a round window.

    `round(t)` wraps the runner's per-round step: the trace starts when
    `t == start` (default 1 — past the round-0 compile), every captured
    round is a `StepTraceAnnotation`, and the trace stops after `count`
    rounds. Failures to start/stop (profiler unavailable on this
    backend, dir not writable) log and disable instead of killing the
    run."""

    def __init__(self, profile_dir: str, start: int = 1, count: int = 3,
                 emitter: Emitter = None):
        self.dir = str(profile_dir)
        self.start = max(0, start)
        self.last = self.start + max(1, count) - 1
        self.emitter = emitter
        self.running = False
        self.broken = False

    def _log(self, msg: str) -> None:
        if self.emitter is not None:
            self.emitter.log(msg, echo=True)
        else:
            print(msg, flush=True)

    @contextlib.contextmanager
    def round(self, t: int) -> Iterator[None]:
        if not self.broken and not self.running and t == self.start:
            try:
                jax.profiler.start_trace(self.dir)
                self.running = True
                self._log(f"[obs] profiler trace started -> {self.dir} "
                          f"(rounds {self.start}..{self.last})")
            except Exception as e:  # backend without profiler support
                self.broken = True
                self._log(f"[obs] profiler unavailable, continuing "
                          f"without trace: {e}")
        if not self.running:
            yield
            return
        try:
            with jax.profiler.StepTraceAnnotation("round", step_num=t):
                yield
        finally:
            if t >= self.last:
                self.stop()

    def stop(self) -> None:
        if self.running:
            try:
                jax.profiler.stop_trace()
                self._log(f"[obs] profiler trace written -> {self.dir}")
            except Exception as e:
                self._log(f"[obs] profiler stop failed: {e}")
            self.running = False
