from repro.optim.sgd import (Optimizer, OptState, sgd, momentum_sgd,
                             adamw, apply_updates, global_norm, clip_by_global_norm)
from repro.optim.schedules import (constant, step_decay, cosine_decay,
                                   warmup_cosine, Schedule)
from repro.optim.pso_optimizer import pso_hybrid, PsoOptState
