"""The paper's Eq.-8 PSO-hybrid update packaged as an `Optimizer`.

This exposes M-DSL's local update through the same (init, update)
interface as sgd/adamw, so the production trainer can swap the paper's
technique in/out with one config flag. The swarm-level state (local best,
global best) is carried in the optimizer state; coefficients are
re-sampled per round via the step's PRNG fold.

    v' = c0 v + c1 (w_l - w) + c2 (w_g - w) - lr * g
    update = v'

The local/global best refresh (Eqs. 9-10) is event-driven on losses, so
it is exposed as a separate `observe(state, params, loss, global_params,
global_loss)` transition rather than inside `update`.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Union

import jax
import jax.numpy as jnp

from repro.core import pso
from repro.optim.schedules import Schedule
from repro.optim.sgd import Optimizer, _as_schedule

Array = jax.Array
PyTree = Any


class PsoOptState(NamedTuple):
    velocity: PyTree
    best_params: PyTree          # w^l (Eq. 9)
    best_loss: Array
    gbest_params: PyTree         # w^g-bar (Eq. 10)
    gbest_loss: Array
    key: Array


def pso_hybrid(lr: Union[float, Schedule], velocity_clip: float = 0.0,
               seed: int = 0) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        inf = jnp.asarray(jnp.inf, jnp.float32)
        return PsoOptState(
            velocity=jax.tree.map(jnp.zeros_like, params),
            best_params=params, best_loss=inf,
            gbest_params=params, gbest_loss=inf,
            key=jax.random.PRNGKey(seed))

    def update(grads, state, params, step):
        key = jax.random.fold_in(state.key, step)
        coeffs = pso.sample_coefficients(key)
        lr_t = sched(step)

        def leaf(w, v, wl, wg, g):
            v_new = (coeffs.c0 * v + coeffs.c1 * (wl - w)
                     + coeffs.c2 * (wg - w) - lr_t * g)
            if velocity_clip > 0.0:
                v_new = jnp.clip(v_new, -velocity_clip, velocity_clip)
            return v_new.astype(w.dtype)

        v_next = jax.tree.map(leaf, params, state.velocity,
                              state.best_params, state.gbest_params, grads)
        return v_next, state._replace(velocity=v_next)

    return Optimizer(init=init, update=update)


def observe(state: PsoOptState, params: PyTree, loss: Array,
            global_params: PyTree, global_loss: Array) -> PsoOptState:
    """Eqs. 9-10 best refresh after a round's evaluation."""
    sel = lambda c, n, o: jax.tree.map(
        lambda a, b: jnp.where(c, a, b), n, o)
    li = loss < state.best_loss
    gi = global_loss < state.gbest_loss
    return state._replace(
        best_params=sel(li, params, state.best_params),
        best_loss=jnp.where(li, loss, state.best_loss),
        gbest_params=sel(gi, global_params, state.gbest_params),
        gbest_loss=jnp.where(gi, global_loss, state.gbest_loss))
