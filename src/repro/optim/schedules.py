"""Learning-rate schedules (pure functions of the int step).

The paper uses an attenuated learning rate alpha_init * gamma^(t // k)
(§V-A: alpha_init=0.01, gamma=0.5) — `step_decay` is that schedule;
the rest are standard production schedules for the mesh trainer.
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]  # step -> lr


def constant(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def step_decay(init_lr: float, gamma: float = 0.5,
               every: int = 10) -> Schedule:
    """Paper §V-A attenuation: lr = init * gamma^(step // every)."""
    def fn(step):
        e = jnp.asarray(step // every, jnp.float32)
        return init_lr * (gamma ** e)
    return fn


def cosine_decay(init_lr: float, total_steps: int,
                 final_frac: float = 0.1) -> Schedule:
    def fn(step):
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return init_lr * (final_frac + (1.0 - final_frac) * cos)
    return fn


def warmup_cosine(init_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1) -> Schedule:
    cos = cosine_decay(init_lr, max(total_steps - warmup_steps, 1),
                       final_frac)
    def fn(step):
        warm = init_lr * step / max(warmup_steps, 1)
        return jnp.where(step < warmup_steps, warm, cos(step - warmup_steps))
    return fn
