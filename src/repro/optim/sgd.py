"""Hand-rolled optimizers over parameter pytrees (no optax offline).

An `Optimizer` is an (init, update) pair in the optax style:

    state = opt.init(params)
    updates, state = opt.update(grads, state, params, step)
    params = apply_updates(params, updates)

Updates are *deltas to add* (the sign is already folded in). All
optimizer states are pytrees of the same structure as the params, so they
shard identically (the mesh trainer reuses the param shardings).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Union

import jax
import jax.numpy as jnp

from repro.optim.schedules import Schedule, constant

Array = jax.Array
PyTree = Any
OptState = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], OptState]
    update: Callable[..., tuple[PyTree, OptState]]  # (grads, state, params, step)


def _as_schedule(lr: Union[float, Schedule]) -> Schedule:
    return constant(lr) if isinstance(lr, (int, float)) else lr


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree: PyTree) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), tree)


def sgd(lr: Union[float, Schedule],
        weight_decay: float = 0.0) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return ()

    def update(grads, state, params, step):
        lr_t = sched(step)
        def leaf(g, p):
            if weight_decay:
                g = g + weight_decay * p.astype(g.dtype)
            return (-lr_t * g).astype(p.dtype)
        return jax.tree.map(leaf, grads, params), state

    return Optimizer(init=init, update=update)


class MomentumState(NamedTuple):
    momentum: PyTree


def momentum_sgd(lr: Union[float, Schedule], beta: float = 0.9,
                 nesterov: bool = False,
                 weight_decay: float = 0.0) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return MomentumState(jax.tree.map(jnp.zeros_like, params))

    def update(grads, state, params, step):
        lr_t = sched(step)

        def mom(m, g, p):
            if weight_decay:
                g = g + weight_decay * p.astype(g.dtype)
            return (beta * m + g).astype(m.dtype)

        m_next = jax.tree.map(mom, state.momentum, grads, params)
        if nesterov:
            upd = jax.tree.map(
                lambda m, g, p: (-lr_t * (beta * m + g)).astype(p.dtype),
                m_next, grads, params)
        else:
            upd = jax.tree.map(lambda m, p: (-lr_t * m).astype(p.dtype),
                               m_next, params)
        return upd, MomentumState(m_next)

    return Optimizer(init=init, update=update)


class AdamWState(NamedTuple):
    mu: PyTree
    nu: PyTree


def adamw(lr: Union[float, Schedule], b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        z = lambda: jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(mu=z(), nu=z())

    def update(grads, state, params, step):
        lr_t = sched(step)
        t = jnp.asarray(step, jnp.float32) + 1.0
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def mu_f(m, g):
            return b1 * m + (1 - b1) * g.astype(jnp.float32)

        def nu_f(v, g):
            g32 = g.astype(jnp.float32)
            return b2 * v + (1 - b2) * g32 * g32

        mu = jax.tree.map(mu_f, state.mu, grads)
        nu = jax.tree.map(nu_f, state.nu, grads)

        def upd(m, v, p):
            step_ = m / c1 / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                step_ = step_ + weight_decay * p.astype(jnp.float32)
            return (-lr_t * step_).astype(p.dtype)

        return jax.tree.map(upd, mu, nu, params), AdamWState(mu, nu)

    return Optimizer(init=init, update=update)
