from repro.sharding.rules import (ShardingRules, logical_to_spec, shard,
                                  set_rules, get_rules, use_rules,
                                  SINGLE_POD_TP, SINGLE_POD_FSDP_TP,
                                  MULTI_POD_TP, MULTI_POD_FSDP_TP, UNSHARDED)
