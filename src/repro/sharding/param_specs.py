"""Per-leaf PartitionSpecs for parameter / cache pytrees, by tree path.

`spec_for_path(path, shape, rules, mesh)` matches the leaf's path suffix
against a table of logical-axis layouts (right-aligned to the leaf rank —
leading stack dims like the scan repetition axis are unsharded), resolves
logical names through the active `ShardingRules`, and *drops any mesh axis
that does not divide the dim* (e.g. smollm's 15 heads on a 16-way model
axis fall back to replicated; the MLP dim still shards). That keeps every
(arch x mesh) combination lowerable without per-arch special cases.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.rules import ShardingRules

# (path regex, logical names right-aligned to the leaf's trailing dims)
_PARAM_TABLE: list[tuple[str, tuple[Optional[str], ...]]] = [
    (r"embed/table$", ("vocab", "embed_fsdp")),
    (r"temporal/wq$", ("embed_fsdp", "heads", None)),
    (r"(temporal|cross)/w[kv]$", ("embed_fsdp", "kv_heads", None)),
    (r"cross/wq$", ("embed_fsdp", "heads", None)),
    (r"(temporal|cross)/wo$", ("heads", None, "embed_fsdp")),
    (r"moe/router$", ("embed_fsdp", None)),
    (r"moe/w[iu]$", ("expert", "embed_fsdp", "expert_mlp")),
    (r"moe/wo$", ("expert", "expert_mlp", "embed_fsdp")),
    (r"dense/w[iu]$", ("embed_fsdp", "mlp")),
    (r"dense/wo$", ("mlp", "embed_fsdp")),
    (r"mlp/w[iu]$", ("embed_fsdp", "mlp")),
    (r"mlp/wo$", ("mlp", "embed_fsdp")),
    # rglru
    (r"temporal/w[xyo]$", ("embed_fsdp", "mlp")),
    (r"temporal/w_[ri]$", ("embed_fsdp", "mlp")),
    (r"temporal/conv$", (None, "mlp")),
    (r"temporal/(b_[ri]|lam)$", ("mlp",)),
    # mlstm / slstm
    (r"temporal/w_(up|gate|in)$", ("embed_fsdp", "mlp")),
    (r"temporal/m[qkv]$", ("embed_fsdp", "mlp")),  # (di, di) in mlstm
    (r"temporal/w_down$", ("mlp", "embed_fsdp")),
    (r"temporal/w_if$", ("embed_fsdp", None)),
    (r"temporal/w_rec$", (None, None, None)),
    (r"temporal/b(_if)?$", (None,)),
    # plain-mlp mixers in attention blocks (non-moe)
    (r"w[iu]$", ("embed_fsdp", "mlp")),
    (r"wo$", ("mlp", "embed_fsdp")),
    (r"(norm|out_norm|final_norm)/scale$", (None,)),
]

_CACHE_TABLE: list[tuple[str, tuple[Optional[str], ...]]] = [
    (r"temporal/[kv]$", ("cache_batch", "cache_seq", "act_kv_heads", None)),
    (r"temporal/pos$", ()),
    (r"cross_kv.*$", (None, "cache_batch", "cache_seq", "act_kv_heads",
                      None)),
    (r"temporal/h$", ("cache_batch", "mlp")),
    (r"temporal/conv$", ("cache_batch", None, "mlp")),
    (r"temporal/C$", ("cache_batch", None, None, None)),
    (r"temporal/[nm]$", ("cache_batch", None, None)),
    (r"temporal/c$", ("cache_batch", "mlp")),
]


def _resolve(names: tuple[Optional[str], ...], shape: tuple[int, ...],
             rules: ShardingRules, mesh: Mesh) -> P:
    """Right-align names to shape; drop axes that don't divide or that an
    earlier dim already uses (e.g. MoE expert dim takes "data" in FSDP
    mode, so embed_fsdp falls back to replicated for expert weights)."""
    ndim = len(shape)
    full = (None,) * (ndim - len(names)) + names
    return _dedup_and_divide(full, shape, rules, mesh)


def _dedup_and_divide(full, shape, rules, mesh) -> P:
    out = []
    used: set[str] = set()
    for dim, name in zip(shape, full):
        # `name` is a logical axis (resolve through rules), an already-
        # resolved mesh axis (use as-is; the worker prefix arrives
        # pre-resolved), or a tuple of mesh axes
        if isinstance(name, str):
            if name in rules:
                axes = rules[name]
            elif name in mesh.axis_names:
                axes = name
            else:
                axes = None
        else:
            axes = name
        if axes is None:
            out.append(None)
            continue
        ax_tuple = (axes,) if isinstance(axes, str) else tuple(axes)
        if any(a in used for a in ax_tuple):
            out.append(None)
            continue
        size = int(np.prod([mesh.shape[a] for a in ax_tuple]))
        if dim % size == 0:
            out.append(axes)
            used.update(ax_tuple)
        else:
            out.append(None)
    return P(*out)


def spec_for_path(path: str, shape: tuple[int, ...], rules: ShardingRules,
                  mesh: Mesh, table: str = "param") -> P:
    tbl = _PARAM_TABLE if table == "param" else _CACHE_TABLE
    for pattern, names in tbl:
        if re.search(pattern, path):
            names = names[:len(shape)] if len(names) > len(shape) else names
            return _resolve(names, shape, rules, mesh)
    return P()  # replicate by default


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(p.name)
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_shardings(tree, rules: ShardingRules, mesh: Mesh,
                   table: str = "param", prefix_axes: int = 0,
                   prefix_spec: Optional[tuple] = None):
    """NamedSharding pytree matching `tree` (of ShapeDtypeStructs or
    arrays). prefix_axes dims at the front get prefix_spec (worker dim)."""

    def leaf(path, x):
        spec = spec_for_path(_path_str(path), x.shape[prefix_axes:], rules,
                             mesh, table)
        if prefix_axes:
            pre = prefix_spec if prefix_spec is not None else (None,) * prefix_axes
            full = tuple(pre) + tuple(spec)
            spec = _dedup_and_divide(full, x.shape, rules, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(leaf, tree)
