"""Logical-axis sharding rules (MaxText-style).

Model code names tensor dims with *logical* axes ("batch", "embed",
"heads", "expert", ...). A `ShardingRules` table maps each logical axis to
mesh axes (or None = replicated). Different deployment modes (pure-TP
swarm, FSDP+TP time-multiplexed swarm, multi-pod) swap the table without
touching model code. `shard(x, names)` applies a with_sharding_constraint
when a mesh is active, and is a no-op otherwise (CPU tests).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, tuple[str, ...]]


class ShardingRules(dict):
    """logical axis name -> mesh axis (str), tuple of axes, or None."""

    def spec(self, names: Sequence[Optional[str]]) -> P:
        """Resolve logical names; a mesh axis already used by an earlier
        dim is dropped from later dims (e.g. MoE expert dim takes "data"
        in FSDP mode, so embed_fsdp inside expert weights replicates)."""
        out = []
        used: set[str] = set()
        for n in names:
            axes = self.get(n) if n is not None else None
            if axes is None:
                out.append(None)
                continue
            ax_tuple = (axes,) if isinstance(axes, str) else tuple(axes)
            if any(a in used for a in ax_tuple):
                out.append(None)
            else:
                out.append(axes)
                used.update(ax_tuple)
        return P(*out)


# --- canonical rule tables -------------------------------------------------
# worker: the swarm dim (spatial workers). batch: per-worker batch.
# embed_fsdp: the FSDP dim of weights (row dim) when FSDP is on.

UNSHARDED = ShardingRules()

SINGLE_POD_TP = ShardingRules(
    worker="data", batch=None, seq=None,
    embed=None, embed_fsdp=None,
    heads="model", kv_heads="model", q_per_kv=None, head_dim=None,
    act_heads="model", act_kv_heads="model", residual_seq="model",
    mlp="model", vocab="model",
    expert="model", expert_mlp=None,
    cache_batch=None, cache_seq=None,
)

SINGLE_POD_FSDP_TP = ShardingRules(
    worker=None, batch="data", seq=None,
    embed=None, embed_fsdp="data",
    heads="model", kv_heads="model", q_per_kv=None, head_dim=None,
    act_heads="model", act_kv_heads="model", residual_seq="model",
    moe_ep=True,
    mlp="model", vocab="model",
    expert="data", expert_mlp="model",
    cache_batch="data", cache_seq=None,
)

MULTI_POD_TP = ShardingRules(
    worker=("pod", "data"), batch=None, seq=None,
    embed=None, embed_fsdp=None,
    heads="model", kv_heads="model", q_per_kv=None, head_dim=None,
    act_heads="model", act_kv_heads="model", residual_seq="model",
    mlp="model", vocab="model",
    expert="model", expert_mlp=None,
    cache_batch=None, cache_seq=None,
)

MULTI_POD_FSDP_TP = ShardingRules(
    worker="pod", batch="data", seq=None,
    embed=None, embed_fsdp="data",
    heads="model", kv_heads="model", q_per_kv=None, head_dim=None,
    act_heads="model", act_kv_heads="model", residual_seq="model",
    mlp="model", vocab="model",
    expert="data", expert_mlp="model",
    cache_batch="data", cache_seq=None,
)

# serving rules are derived by the launcher (batch over data, cache over
# data; long-context: cache_seq over data) — see launch/shardings.py.

_state = threading.local()


def set_rules(rules: Optional[ShardingRules], mesh: Optional[Mesh]) -> None:
    _state.rules = rules
    _state.mesh = mesh


def get_rules() -> tuple[Optional[ShardingRules], Optional[Mesh]]:
    return getattr(_state, "rules", None), getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules], mesh: Optional[Mesh]):
    prev = get_rules()
    set_rules(rules, mesh)
    try:
        yield
    finally:
        set_rules(*prev)


def logical_to_spec(names: Sequence[Optional[str]]) -> Optional[P]:
    rules, _ = get_rules()
    if rules is None:
        return None
    return rules.spec(names)


def shard(x: jax.Array, names: Sequence[Optional[str]]) -> jax.Array:
    """Apply a sharding constraint if rules+mesh are active, else no-op."""
    rules, mesh = get_rules()
    if rules is None or mesh is None:
        return x
    spec = rules.spec(names)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
