"""Minimal deterministic stand-in for `hypothesis` (gated dependency).

The container may not ship hypothesis; rather than skip the property
tests, `conftest.py` installs this module under the `hypothesis` /
`hypothesis.strategies` names when the real package is unavailable.

It implements the tiny API surface the test-suite uses — `given`,
`settings`, `assume`, and the `integers` / `floats` / `lists` /
`sampled_from` / `booleans` strategies — with a seeded RNG derived from
the test's qualified name, so runs are reproducible (no shrinking, no
database). Real hypothesis, when installed, takes precedence.
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np


class Strategy:
    """A strategy is just a draw function over a numpy RandomState."""

    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: np.random.RandomState):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> Strategy:
    lo, hi = int(min_value), int(max_value)
    return Strategy(lambda rng: int(rng.randint(lo, hi + 1)))


def floats(min_value=0.0, max_value=1.0, allow_nan=None,
           allow_infinity=None, width=64) -> Strategy:
    lo, hi = float(min_value), float(max_value)
    return Strategy(lambda rng: float(rng.uniform(lo, hi)))


def booleans() -> Strategy:
    return Strategy(lambda rng: bool(rng.randint(0, 2)))


def sampled_from(elements) -> Strategy:
    pool = list(elements)
    return Strategy(lambda rng: pool[int(rng.randint(0, len(pool)))])


def lists(elements: Strategy, min_size: int = 0, max_size: int = 10,
          unique: bool = False) -> Strategy:
    def draw(rng):
        n = int(rng.randint(min_size, max_size + 1))
        if not unique:
            return [elements.example(rng) for _ in range(n)]
        out: list = []
        attempts = 0
        while len(out) < n and attempts < 1000:
            v = elements.example(rng)
            if v not in out:
                out.append(v)
            attempts += 1
        return out

    return Strategy(draw)


class UnsatisfiedAssumption(Exception):
    pass


def assume(condition) -> bool:
    if not condition:
        raise UnsatisfiedAssumption()
    return True


def settings(max_examples: int = 25, deadline=None, **_kw):
    """Records max_examples on the decorated function; `given` reads it
    whether settings is applied inside or outside of it."""

    def deco(fn):
        fn._hs_max_examples = max_examples
        return fn

    return deco


class HealthCheck:  # referenced via settings(suppress_health_check=...)
    all = ()
    function_scoped_fixture = None
    too_slow = None


def given(*strategies: Strategy, **kw_strategies: Strategy):
    """Positional strategies bind to the RIGHTMOST parameters (matching
    hypothesis); everything to their left (self, pytest fixtures) is left
    for pytest to supply. The wrapper exposes the reduced signature so
    pytest's fixture resolution never sees the drawn parameters."""

    def deco(fn):
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        n_pos = len(strategies)
        kept = params[: len(params) - n_pos] if n_pos else params
        # pytest supplies the surviving params (self, fixtures) by
        # keyword, so drawn values are bound by name too
        drawn_names = [p.name for p in params[len(params) - n_pos:]]
        if kw_strategies:
            kept = [p for p in kept if p.name not in kw_strategies]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            max_ex = getattr(wrapper, "_hs_max_examples", 25)
            seed0 = zlib.adler32(fn.__qualname__.encode("utf-8"))
            ran = 0
            attempt = 0
            while ran < max_ex and attempt < max_ex * 5:
                rng = np.random.RandomState((seed0 + attempt) % (2 ** 32))
                drawn = {n: s.example(rng)
                         for n, s in zip(drawn_names, strategies)}
                drawn.update({k: s.example(rng)
                              for k, s in kw_strategies.items()})
                attempt += 1
                try:
                    fn(*args, **kwargs, **drawn)
                except UnsatisfiedAssumption:
                    continue
                ran += 1

        wrapper.__signature__ = sig.replace(parameters=kept)
        wrapper.is_hypothesis_test = True
        return wrapper

    return deco
