import importlib.util
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Gate the hypothesis dependency: if the real package is missing, install
# the deterministic stub (tests/_hypothesis_stub.py) under its name so
# the property-test modules still collect and run.
if importlib.util.find_spec("hypothesis") is None:
    import types

    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub as _stub

    _hyp = types.ModuleType("hypothesis")
    for _name in ("given", "settings", "assume", "HealthCheck", "Strategy",
                  "UnsatisfiedAssumption"):
        setattr(_hyp, _name, getattr(_stub, _name))
    _st = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "floats", "booleans", "lists", "sampled_from"):
        setattr(_st, _name, getattr(_stub, _name))
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
