"""repro.comm — compressor invariants, error-feedback telescoping,
channel semantics through Eq. 7, Byzantine robustness of selection, and
quant-pack kernel/oracle equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import budget, channel, compress
from repro.comm.budget import CommConfig
from repro.core import mdsl
from repro.core.mdsl import MdslConfig
from repro.core.pso import PsoHyperParams

KEY = jax.random.PRNGKey(0)

TREE = {"w": jax.random.normal(KEY, (300, 7)),
        "b": jax.random.normal(jax.random.fold_in(KEY, 1), (11,))}


class TestCompressors:
    def test_identity_roundtrip(self):
        cfg = CommConfig(compressor="identity")
        wire = compress.compress(cfg, TREE, KEY)
        for k in TREE:
            np.testing.assert_array_equal(wire[k], TREE[k])
        assert budget.payload_bytes(cfg, TREE) == budget.dense_bytes(TREE)

    @pytest.mark.parametrize("ratio", [0.01, 0.1, 0.5])
    def test_topk_keeps_largest_and_zeroes_rest(self, ratio):
        cfg = CommConfig(compressor="topk", topk_ratio=ratio)
        wire = compress.compress(cfg, TREE, KEY)
        for k in TREE:
            n = TREE[k].size
            kk = budget.topk_count(n, ratio)
            w = np.asarray(wire[k]).reshape(-1)
            x = np.asarray(TREE[k]).reshape(-1)
            nz = np.nonzero(w)[0]
            assert len(nz) <= kk
            np.testing.assert_array_equal(w[nz], x[nz])  # values unchanged
            # kept entries are the largest-|.| ones
            if len(nz):
                assert np.abs(x[nz]).min() >= np.partition(
                    np.abs(x), -kk)[-kk] - 1e-7
        # payload is strictly smaller than dense
        assert budget.payload_bytes(cfg, TREE) < budget.dense_bytes(TREE)

    @pytest.mark.parametrize("name,bits", [("int8", 8), ("int4", 4)])
    def test_quantized_error_bounded_by_scale(self, name, bits):
        cfg = CommConfig(compressor=name)
        wire = compress.compress(cfg, TREE, KEY)
        qmax = 127.0 if bits == 8 else 7.0
        for k in TREE:
            x = np.asarray(TREE[k], np.float32)
            scale = np.abs(x).max() / qmax  # single block at this size
            err = np.abs(np.asarray(wire[k], np.float32) - x)
            assert err.max() <= scale + 1e-6  # stochastic floor: < 1 step
        dense = budget.dense_bytes(TREE)
        payload = budget.payload_bytes(cfg, TREE)
        assert payload < dense
        # byte-accurate: n*b/8 (+ one f32 scale per block per leaf)
        expect = sum(-(-x.size * bits // 8) + 4 for x in TREE.values())
        assert payload == expect

    def test_compression_ratio_ordering(self):
        ratios = [budget.dense_bytes(TREE) / budget.payload_bytes(
            CommConfig(compressor=c, topk_ratio=0.05), TREE)
            for c in ("identity", "int8", "int4", "topk")]
        ident, int8, int4, topk = ratios
        assert ident == 1.0
        assert 3.5 < int8 <= 4.0       # ~4x plus scale overhead
        assert 7.0 < int4 <= 8.0
        assert topk > int4             # 5% topk beats 4-bit


class TestErrorFeedback:
    def _run_compressed_sgd(self, cfg, steps=60, lr=0.2):
        """1-worker quadratic: min ||x - t||^2, uplink-compressed updates
        applied to a server copy with error feedback."""
        t = jnp.asarray([1.0, -2.0, 0.5, 3.0, -0.7, 0.1, 2.2, -1.4])
        x_server = jnp.zeros(8)
        x_local = jnp.zeros(8)
        res = compress.init_residual({"x": x_local})
        key = KEY
        for s in range(steps):
            key, k = jax.random.split(key)
            delta = {"x": -lr * 2.0 * (x_local - t)}
            wire, res = compress.compress_with_ef(cfg, delta, res, k)
            x_server = x_server + wire["x"]
            x_local = x_local + delta["x"]  # worker keeps its exact step
        return x_server, x_local, res

    @pytest.mark.parametrize("comp", ["topk", "int8", "int4"])
    def test_residual_telescopes_to_uncompressed(self, comp):
        cfg = CommConfig(compressor=comp, topk_ratio=0.25)
        x_server, x_local, res = self._run_compressed_sgd(cfg)
        # telescoping: server = sum of wires = sum of deltas - residual
        np.testing.assert_allclose(np.asarray(x_server + res["x"]),
                                   np.asarray(x_local), rtol=1e-5,
                                   atol=1e-5)
        # and the compressed trajectory lands near the optimum
        np.testing.assert_allclose(np.asarray(x_server),
                                   np.asarray(x_local), atol=0.15)

    def test_no_error_feedback_drops_error(self):
        cfg = CommConfig(compressor="topk", topk_ratio=0.25,
                         error_feedback=False)
        _, _, res = self._run_compressed_sgd(cfg, steps=5)
        np.testing.assert_array_equal(np.asarray(res["x"]), 0.0)

    def test_select_residual_only_advances_selected(self):
        old = {"x": jnp.ones((4, 3))}
        new = {"x": jnp.full((4, 3), 7.0)}
        mask = jnp.asarray([1.0, 0.0, 1.0, 0.0])
        out = compress.select_residual(mask, new, old)
        np.testing.assert_array_equal(np.asarray(out["x"][:, 0]),
                                      [7.0, 1.0, 7.0, 1.0])


class TestChannel:
    def _deltas(self, C=4, n=6):
        d = jax.random.normal(KEY, (C, n))
        return {"x": d}

    def test_ideal_is_masked_mean(self):
        cfg = CommConfig()
        g = {"x": jnp.zeros(6)}
        wire = self._deltas()
        mask = jnp.asarray([1.0, 0.0, 1.0, 1.0])
        out, mask_eff = channel.receive(cfg, g, wire, mask, KEY)
        np.testing.assert_array_equal(np.asarray(mask_eff), np.asarray(mask))
        want = np.asarray(wire["x"])[[0, 2, 3]].mean(axis=0)
        np.testing.assert_allclose(np.asarray(out["x"]), want, rtol=1e-6)

    def test_erasure_preserves_masked_mean_normalization(self):
        """A dropped upload must fall out of Eq. 7's mean: the denominator
        is the survivor count, not the selected count."""
        cfg = CommConfig(channel="erasure", drop_prob=0.5)
        g = {"x": jnp.zeros(6)}
        wire = self._deltas()
        mask = jnp.ones((4,))
        seen_partial = False
        key = KEY
        for s in range(30):
            key, k = jax.random.split(key)
            out, mask_eff = channel.receive(cfg, g, wire, mask, k)
            surv = np.asarray(mask_eff).astype(bool)
            if 0 < surv.sum() < 4:
                seen_partial = True
                want = np.asarray(wire["x"])[surv].mean(axis=0)
                np.testing.assert_allclose(np.asarray(out["x"]), want,
                                           rtol=1e-5)
            if surv.sum() == 0:  # all lost: w_t unchanged, not corrupted
                np.testing.assert_array_equal(np.asarray(out["x"]), 0.0)
        assert seen_partial

    def test_awgn_noise_scales_with_snr(self):
        g = {"x": jnp.zeros(512)}
        wire = {"x": jnp.broadcast_to(
            jax.random.normal(KEY, (512,)), (2, 512))}
        mask = jnp.ones((2,))
        clean, _ = channel.receive(CommConfig(), g, wire, mask, KEY)
        errs = {}
        for snr in (0.0, 20.0):
            out, _ = channel.receive(
                CommConfig(channel="awgn", snr_db=snr), g, wire, mask, KEY)
            errs[snr] = float(jnp.abs(out["x"] - clean["x"]).max())
        assert errs[20.0] < errs[0.0]
        assert errs[20.0] > 0.0

    def test_byzantine_sign_flip_corrupts_last_workers(self):
        cfg = CommConfig(byzantine=2)
        prev = {"x": jnp.zeros((5, 3))}
        new = {"x": jnp.ones((5, 3))}
        out = channel.corrupt_local_updates(cfg, prev, new, KEY)
        np.testing.assert_array_equal(np.asarray(out["x"][:3]), 1.0)
        np.testing.assert_array_equal(np.asarray(out["x"][3:]), -1.0)


class TestEngineIntegration:
    def _run(self, algorithm, comm, rounds=8, C=8, seed=0):
        din, L = 6, 3
        key = jax.random.PRNGKey(seed)
        w_true = jax.random.normal(key, (din, L))
        xs = jax.random.normal(jax.random.fold_in(key, 1), (C, 64, din))
        ys = jnp.argmax(jnp.einsum("cnd,dl->cnl", xs, w_true), axis=-1)
        gx = jax.random.normal(jax.random.fold_in(key, 2), (128, din))
        gy = jnp.argmax(gx @ w_true, axis=-1)

        def init(k):
            return {"w": 0.01 * jax.random.normal(k, (din, L)),
                    "b": jnp.zeros((L,))}

        def loss_fn(p, x, y):
            logits = x @ p["w"] + p["b"]
            logp = jax.nn.log_softmax(logits)
            return -jnp.take_along_axis(logp, y[..., None], -1).mean()

        cfg = MdslConfig(algorithm=algorithm, local_epochs=2, batch_size=32,
                         hp=PsoHyperParams(learning_rate=0.3,
                                           velocity_clip=0.1), comm=comm)
        state = mdsl.init_state(jax.random.fold_in(key, 3), init, C,
                                eta=jnp.zeros((C,)))
        n_params = mdsl.count_params(state.global_params)
        hist = []
        for r in range(rounds):
            state, m = mdsl.mdsl_round(
                state, xs, ys, gx, gy, jax.random.fold_in(key, 100 + r),
                loss_fn=loss_fn, eval_fn=loss_fn, cfg=cfg,
                n_params=n_params)
            hist.append(m)
        acc = float((jnp.argmax(
            gx @ state.global_params["w"] + state.global_params["b"],
            axis=-1) == gy).mean())
        return state, hist, acc, n_params

    def test_default_comm_matches_seed_accounting(self):
        _, hist, acc, n = self._run("mdsl", CommConfig())
        for m in hist:
            assert float(m.bytes_up) == pytest.approx(
                float(m.selected_count) * n * 4)
            assert float(m.delivered_count) == float(m.selected_count)
            assert float(m.compression_ratio) == 1.0
        assert acc > 0.5

    def test_compressed_bytes_below_dense_and_still_learns(self):
        comm = CommConfig(compressor="topk", topk_ratio=0.25)
        _, hist, acc, n = self._run("mdsl", comm)
        _, _, acc0, _ = self._run("mdsl", CommConfig())
        for m in hist:
            assert float(m.bytes_up) < float(m.selected_count) * n * 4
        assert acc > acc0 - 0.15  # compressed run stays in the same league

    def test_erasure_round_with_all_drops_is_safe(self):
        comm = CommConfig(channel="erasure", drop_prob=0.9)
        state, hist, _, _ = self._run("mdsl", comm, rounds=4)
        for m in hist:
            assert float(m.delivered_count) <= float(m.selected_count)
        for leaf in jax.tree.leaves(state.global_params):
            assert bool(jnp.isfinite(leaf).all())

    def test_byzantine_degrades_fedavg_more_than_mdsl(self):
        """CB-DSL's claim at toy scale: function-value selection rejects
        Byzantine workers, averaging over everyone does not."""
        comm = CommConfig(byzantine=2, byzantine_mode="sign_flip")
        _, _, acc_fed, _ = self._run("fedavg", comm, rounds=8)
        _, hist, acc_mdsl, _ = self._run("mdsl", comm, rounds=8)
        assert acc_mdsl > acc_fed
        # after warm-up, selection should shut the byzantine workers out
        late_masks = np.stack([np.asarray(m.mask) for m in hist[2:]])
        assert late_masks[:, -2:].mean() < late_masks[:, :-2].mean()


class TestQuantPackKernel:
    @pytest.mark.parametrize("bits", [8, 4])
    @pytest.mark.parametrize("rows", [256, 1024])
    def test_kernel_matches_ref_interpret(self, bits, rows):
        from repro.kernels.quant_pack import quant_pack_2d, quant_pack_ref
        x = jax.random.normal(jax.random.fold_in(KEY, rows), (rows, 128))
        pk, sk = quant_pack_2d(x, jnp.int32(13), bits=bits, interpret=True)
        pr, sr = quant_pack_ref(x, jnp.int32(13), bits=bits)
        np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
        np.testing.assert_array_equal(np.asarray(sk), np.asarray(sr))

    @pytest.mark.parametrize("bits", [8, 4])
    def test_roundtrip_error_bound(self, bits):
        from repro.kernels.quant_pack import (dequant_unpack_ref,
                                              quant_pack_ref)
        x = jax.random.normal(KEY, (512, 128))
        packed, scales = quant_pack_ref(x, jnp.int32(5), bits=bits)
        xh = dequant_unpack_ref(packed, scales, bits=bits)
        qmax = 127.0 if bits == 8 else 7.0
        step = float(jnp.abs(x).max()) / qmax
        assert float(jnp.abs(xh - x).max()) <= step + 1e-6

    def test_stochastic_rounding_unbiased(self):
        from repro.kernels.quant_pack import quant_dequant
        x = jnp.full((256 * 128,), 0.37)
        errs = []
        for seed in range(8):
            xh = quant_dequant(x, jnp.int32(seed), bits=4)
            errs.append(float((xh - x).mean()))
        step = 0.37 / 7.0
        assert abs(np.mean(errs)) < 0.05 * step
