"""Unit tests for the HLO cost model's byte accounting specifics
(dynamic-slice aliasing, collective payloads, f32-inflation detector)."""
import jax
import jax.numpy as jnp

from repro.launch import hlo_costmodel


def _analyze(fn, *args):
    text = jax.jit(fn).lower(*args).compile().as_text()
    return hlo_costmodel.analyze(text), text


class TestDusBytes:
    def test_cache_update_charges_slice_not_buffer(self):
        cache = jnp.zeros((8, 4096, 64))
        upd = jnp.ones((8, 1, 64))

        def f(cache, upd, i):
            return jax.lax.dynamic_update_slice(cache, upd, (0, i, 0))

        # donated: the cache aliases in place (the serving configuration)
        text = jax.jit(f, donate_argnums=(0,)).lower(
            cache, upd, jnp.int32(7)).compile().as_text()
        rec = hlo_costmodel.analyze(text)
        buf_bytes = 8 * 4096 * 64 * 4
        # traffic must be near the slice size, far below the buffer
        assert rec["hbm_bytes"] < buf_bytes // 4

    def test_plain_copy_counts_both_sides(self):
        x = jnp.zeros((1024, 1024))
        rec, _ = _analyze(lambda x: (x * 2.0).T.copy(), x)
        assert rec["hbm_bytes"] >= 2 * x.size * 4


class TestCollectivePayload:
    def test_psum_bytes(self):
        # single-device: GSPMD emits no collective; exercise the parser
        # on a synthetic HLO instead
        hlo = """
HloModule m

ENTRY %main (p0: f32[128,256]) -> f32[128,256] {
  %p0 = f32[128,256]{1,0} parameter(0)
  ROOT %ar = f32[128,256]{1,0} all-reduce(%p0), replica_groups={}, to_apply=%add
}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}
"""
        rec = hlo_costmodel.analyze(hlo)
        assert rec["collectives"]["by_kind_bytes"]["all-reduce"] == \
            128 * 256 * 4
        assert rec["collectives"]["by_kind_count"]["all-reduce"] == 1


class TestInflationDetector:
    def test_wrapped_convert_detected(self):
        hlo = """
HloModule m

%wrapped_convert_computation (p: bf16[64,64]) -> f32[64,64] {
  %p = bf16[64,64]{1,0} parameter(0)
  ROOT %c = f32[64,64]{1,0} convert(%p)
}

ENTRY %main (p0: bf16[64,64]) -> f32[64,64] {
  %p0 = bf16[64,64]{1,0} parameter(0)
  ROOT %wrapped_convert = f32[64,64]{1,0} fusion(%p0), kind=kLoop, calls=%wrapped_convert_computation
}
"""
        rec = hlo_costmodel.analyze(hlo)
        assert rec["host_f32_inflation_bytes"] == 64 * 64 * 4 // 2
