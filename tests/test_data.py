"""Data pipeline invariants: partition shapes, Dirichlet skew behaviour,
determinism."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import noniid
from repro.data import partition, synthetic

SPEC = synthetic.MNIST_LIKE


def test_partition_shapes():
    d = partition.dirichlet_partition(jax.random.PRNGKey(0), 6, 0.5, SPEC,
                                      n_local=64, n_global=128, n_test=32)
    assert d.x.shape == (6, 64, SPEC.height, SPEC.width, SPEC.channels)
    assert d.y.shape == (6, 64)
    assert d.global_x.shape[0] == 128 and d.test_x.shape[0] == 32
    assert int(d.y.max()) < SPEC.num_classes and int(d.y.min()) >= 0


def test_small_alpha_concentrates_labels():
    """alpha=0.05 workers see far fewer distinct labels than alpha=100."""
    k = jax.random.PRNGKey(0)
    def mean_distinct(alpha):
        d = partition.dirichlet_partition(k, 12, alpha, SPEC, n_local=256,
                                          n_global=64, n_test=16)
        return np.mean([len(np.unique(np.asarray(d.y[i])))
                        for i in range(12)])
    assert mean_distinct(0.05) < mean_distinct(100.0) - 3


def test_eta_tracks_alpha():
    """Mean non-iid degree decreases as alpha grows (metric validity —
    the Fig. 1 trend)."""
    k = jax.random.PRNGKey(1)
    means = []
    for alpha in (0.05, 0.5, 5.0, 50.0):
        d = partition.dirichlet_partition(k, 16, alpha, SPEC, n_local=256,
                                          n_global=512, n_test=16)
        ratios, wds = [], []
        for i in range(16):
            r, w = noniid.noniid_features(d.y[i], d.global_y,
                                          SPEC.num_classes)
            ratios.append(float(r))
            wds.append(float(w))
        # raw heterogeneity features: low ratio / high WD at small alpha
        means.append((np.mean(ratios), np.mean(wds)))
    ratios_m = [m[0] for m in means]
    wds_m = [m[1] for m in means]
    assert ratios_m == sorted(ratios_m), ratios_m          # increasing
    assert wds_m == sorted(wds_m, reverse=True), wds_m     # decreasing


def test_mixed_partition_case2_groups():
    groups = [(4, 0.1), (3, 0.5), (2, 1.0), (1, 10.0)]
    d = partition.mixed_dirichlet_partition(jax.random.PRNGKey(2), groups,
                                            SPEC, n_local=64, n_global=64,
                                            n_test=16)
    assert d.x.shape[0] == 10
    assert np.allclose(np.asarray(d.alphas[:4]), 0.1)
    assert float(d.alphas[-1]) == 10.0


def test_determinism():
    a = partition.dirichlet_partition(jax.random.PRNGKey(3), 4, 0.5, SPEC,
                                      n_local=32, n_global=32, n_test=16)
    b = partition.dirichlet_partition(jax.random.PRNGKey(3), 4, 0.5, SPEC,
                                      n_local=32, n_global=32, n_test=16)
    np.testing.assert_array_equal(np.asarray(a.x), np.asarray(b.x))
    np.testing.assert_array_equal(np.asarray(a.y), np.asarray(b.y))


def test_classes_are_learnable():
    """A linear probe on i.i.d. synthetic data beats chance easily."""
    d = partition.iid_partition(jax.random.PRNGKey(4), 2, SPEC,
                                n_local=512, n_global=512, n_test=512)
    x = d.global_x.reshape(512, -1)
    y = d.global_y
    # closed-form ridge regression to one-hot targets
    oh = jax.nn.one_hot(y, SPEC.num_classes)
    w = jnp.linalg.solve(x.T @ x + 10.0 * jnp.eye(x.shape[1]), x.T @ oh)
    pred = jnp.argmax(d.test_x.reshape(512, -1) @ w, axis=-1)
    acc = float((pred == d.test_y).mean())
    assert acc > 0.5, acc
