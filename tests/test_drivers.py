"""Integration tests for the train/serve drivers (tiny settings)."""
import jax.numpy as jnp
import pytest

from repro.launch.serve import serve
from repro.launch.train import (_noniid2_groups, make_case_data,
                                run_mesh_training, run_paper_experiment)


class TestPaperDriver:
    def test_mdsl_short_run_structure(self):
        rec = run_paper_experiment(
            algorithm="mdsl", case="noniid1", dataset="mnist_like",
            rounds=2, num_workers=4, width_mult=2, local_epochs=1,
            n_local=128, verbose=False)
        assert len(rec["acc"]) == 2
        assert len(rec["selected"]) == 2
        assert all(1 <= s <= 4 for s in rec["selected"])
        assert rec["n_params"] > 0
        # uploads accounted per §IV-C
        assert rec["uploaded_params"][0] == rec["selected"][0] * rec["n_params"]

    def test_noniid2_groups_scale(self):
        assert sum(c for c, _ in _noniid2_groups(50)) == 50
        assert sum(c for c, _ in _noniid2_groups(10)) == 10
        assert _noniid2_groups(50)[0] == (20, 0.1)

    def test_case_data_shapes(self):
        data, spec = make_case_data("noniid2", "mnist_like", 10, 0,
                                    n_local=64)
        assert data.x.shape == (10, 64, 28, 28, 1)
        assert data.alphas.shape == (10,)


class TestMeshDriver:
    def test_reduced_arch_trains(self):
        rec = run_mesh_training("smollm-360m", steps=2, num_spatial=2,
                                seq_len=32, per_worker_batch=2,
                                verbose=False)
        assert len(rec["global_loss"]) == 2
        assert all(jnp.isfinite(jnp.asarray(rec["global_loss"])))

    def test_checkpointing(self, tmp_path):
        rec = run_mesh_training("stablelm-3b", steps=2, num_spatial=1,
                                seq_len=16, per_worker_batch=1,
                                ckpt_dir=str(tmp_path), verbose=False)
        assert rec["ckpt_steps"] == [0, 1]


class TestServeDriver:
    @pytest.mark.parametrize("arch", ["smollm-360m", "xlstm-350m"])
    def test_serve_reduced(self, arch):
        rec = serve(arch, batch=2, prompt_len=8, gen_len=4, reduced=True,
                    verbose=False)
        assert rec["output_shape"] == [2, 4]

    def test_serve_temperature_sampling(self):
        rec = serve("smollm-360m", batch=1, prompt_len=8, gen_len=4,
                    temperature=1.0, reduced=True, verbose=False)
        assert rec["output_shape"] == [1, 4]
