"""repro.experiments: spec round-trip, registry completeness, override
parsing, and the golden-pinned legacy shims.

The golden file (tests/golden/paper_default_mdsl.json) was captured
from `run_paper_experiment` *before* the runner refactor (commit
51e0a69's code) at a small deterministic config; the shim must keep
emitting identical metrics (modulo timing) on the default path.
"""
import json
from pathlib import Path

import hypothesis as hp
import hypothesis.strategies as st
import pytest

from repro.experiments import (ExperimentSpec, build, from_dict,
                               get_scenario, list_scenarios, override,
                               run, sweep, to_dict)

GOLDEN = Path(__file__).parent / "golden" / "paper_default_mdsl.json"

# shrink overrides so registry specs build/run in test time
TINY_PAPER = ("data.num_workers=4", "data.n_local=64", "run.rounds=1",
              "model.width_mult=2", "algo.local_epochs=1")
TINY_MESH = ("data.num_workers=2", "model.seq_len=16",
             "model.per_worker_batch=1", "run.rounds=1")


def tiny(spec: ExperimentSpec) -> ExperimentSpec:
    ovr = TINY_PAPER if spec.model.kind == "paper" else TINY_MESH
    spec = override(spec, *ovr)
    # keep byzantine fleets consistent with the shrunk worker count
    # (validate() bounds byzantine and floor(trim_ratio*K) against the
    # shrunk per-round cohort K=4)
    if spec.comm.byzantine:
        spec = override(spec, "comm.byzantine=1")
        if spec.comm.aggregator == "trimmed_mean":
            spec = override(spec, "comm.trim_ratio=0.3")
    # shrink fleet presets with the cohort: P=64 registered, K=4 active
    if spec.fleet.population:
        spec = override(spec, "fleet.population=64", "fleet.cohort_size=4")
    # clamp quorum with the cohort (validate() rejects quorum > K)
    if spec.comm.quorum and spec.comm.quorum > spec.data.num_workers:
        spec = override(spec, f"comm.quorum={spec.data.num_workers}")
    return spec


class TestSpecRoundTrip:
    @pytest.mark.parametrize("name", list_scenarios())
    def test_json_round_trip(self, name):
        spec = get_scenario(name)
        wire = json.loads(json.dumps(to_dict(spec)))
        assert from_dict(wire) == spec

    def test_round_trip_preserves_tuples(self):
        spec = override(ExperimentSpec(), "data.eta_coeffs=0.1,0.2,0.3")
        back = from_dict(json.loads(json.dumps(to_dict(spec))))
        assert back.data.eta_coeffs == (0.1, 0.2, 0.3)
        assert back == spec

    def test_unknown_field_rejected(self):
        d = to_dict(ExperimentSpec())
        d["data"]["num_gpus"] = 8
        with pytest.raises(ValueError, match="num_gpus"):
            from_dict(d)

    @hp.given(st.sampled_from(list_scenarios()),
              st.integers(min_value=0, max_value=999),
              st.sampled_from(["identity", "topk", "int8", "int4"]),
              st.floats(min_value=1e-3, max_value=1.0))
    @hp.settings(max_examples=25, deadline=None)
    def test_round_trip_under_random_overrides(self, name, seed, comp,
                                               ratio):
        spec = override(get_scenario(name), f"run.seed={seed}",
                        f"comm.compressor={comp}",
                        f"comm.topk_ratio={ratio}")
        assert from_dict(json.loads(json.dumps(to_dict(spec)))) == spec


class TestRegistry:
    def test_expected_presets_present(self):
        names = list_scenarios()
        for required in ["paper/fig3-iid", "paper/fig3-noniid1",
                         "paper/fig3-noniid2", "byzantine-median",
                         "low-bandwidth-int4", "lossy-uplink-erasure",
                         "adaptive-tiers", "mesh/smollm-smoke",
                         "quickstart"]:
            assert required in names

    @pytest.mark.parametrize("name", list_scenarios())
    def test_every_preset_validates(self, name):
        spec = get_scenario(name)
        assert spec.validate() is spec
        assert spec.name == name

    def test_unknown_scenario_lists_available(self):
        with pytest.raises(ValueError, match="paper/fig3-noniid1"):
            get_scenario("nope")

    @pytest.mark.parametrize(
        "name", [n for n in list_scenarios() if "mesh" not in n])
    def test_paper_presets_build_runnable_step(self, name):
        prep = build(tiny(get_scenario(name)))
        assert prep.n_params > 0
        state, telemetry, key = prep.step(prep.state, prep.key)
        assert int(telemetry.selected_count) >= 1

    def test_mesh_preset_builds_runnable_step(self):
        prep = build(tiny(get_scenario("mesh/smollm-smoke")))
        assert prep.n_params > 0
        state, info, key = prep.step(prep.state, prep.key)
        assert float(info.global_loss) > 0


class TestOverride:
    def test_type_coercion(self):
        s = override(ExperimentSpec(), "run.rounds=3", "algo.tau=0.5",
                     "comm.adaptive_bits=true", "model.name=resnet",
                     "algo.hp.learning_rate=0.2", "run.out=none")
        assert s.run.rounds == 3 and s.algo.tau == 0.5
        assert s.comm.adaptive_bits is True
        assert s.model.name == "resnet"
        assert s.algo.hp.learning_rate == 0.2
        assert s.run.out is None

    def test_original_spec_unchanged(self):
        base = ExperimentSpec()
        override(base, "run.rounds=99")
        assert base.run.rounds == 20

    @pytest.mark.parametrize("bad", [
        "comm.warp_drive=1",          # unknown leaf
        "nope.rounds=1",              # unknown group
        "run.rounds.deeper=1",        # path through a scalar
        "run.rounds",                 # no assignment
        "run.rounds=three",           # uncoercible int
        "run.rounds=none",            # None into a non-Optional field
        "comm.adaptive_bits=maybe",   # uncoercible bool
        "=5",                         # empty path
    ])
    def test_rejects_bad_overrides(self, bad):
        with pytest.raises(ValueError):
            override(ExperimentSpec(), bad)

    def test_validate_catches_bad_enums(self):
        with pytest.raises(ValueError, match="compressor"):
            override(ExperimentSpec(), "comm.compressor=zip").validate()
        with pytest.raises(ValueError, match="algorithm"):
            override(ExperimentSpec(), "algo.algorithm=sgd").validate()
        with pytest.raises(ValueError, match="rounds"):
            override(ExperimentSpec(), "run.rounds=0").validate()

    def test_alpha_only_valid_on_dirichlet_case(self):
        # alpha shapes only the noniid1 partition; silently ignoring it
        # elsewhere would fake a sweep axis
        override(ExperimentSpec(), "data.alpha=0.1").validate()
        with pytest.raises(ValueError, match="alpha"):
            override(ExperimentSpec(), "data.case=noniid2",
                     "data.alpha=0.1").validate()
        with pytest.raises(ValueError, match="alpha"):
            override(ExperimentSpec(), "data.alpha=-1.0").validate()

    def test_none_allowed_into_optional_fields(self):
        s = override(ExperimentSpec(), "data.alpha=0.5")
        assert override(s, "data.alpha=none").data.alpha is None
        assert override(s, "run.ckpt_dir=none").run.ckpt_dir is None

    def test_validate_rejects_fully_byzantine_fleet(self):
        with pytest.raises(ValueError, match="byzantine"):
            override(ExperimentSpec(), "data.num_workers=3",
                     "comm.byzantine=3").validate()
        with pytest.raises(ValueError, match="byzantine"):
            override(ExperimentSpec(), "comm.byzantine=-1").validate()
        # a minority attack is a legitimate experiment
        override(ExperimentSpec(), "data.num_workers=4",
                 "comm.byzantine=3").validate()


class _Captured(Exception):
    pass


class TestCliMapping:
    def _spec_for(self, monkeypatch, argv):
        import sys

        import repro.launch.train as train
        monkeypatch.setattr(sys, "argv", ["train.py"] + argv)
        seen = {}

        def fake_run(spec, verbose=True):
            seen["spec"] = spec
            raise _Captured

        monkeypatch.setattr(train, "run", fake_run)
        with pytest.raises(_Captured):
            train.main()
        return seen["spec"]

    def test_scenario_plus_set_and_legacy_flag(self, monkeypatch):
        spec = self._spec_for(monkeypatch, [
            "--scenario", "paper/fig3-noniid1", "--set", "run.rounds=2",
            "--rounds", "7", "--compressor", "int8"])
        # --set wins over the legacy flag; comm flag mapped through
        assert spec.run.rounds == 2
        assert spec.comm.compressor == "int8"
        assert spec.data.case == "noniid1"

    def test_pure_legacy_flags_build_a_spec(self, monkeypatch):
        spec = self._spec_for(monkeypatch, [
            "--mode", "paper", "--algorithm", "fedavg", "--case", "noniid2",
            "--rounds", "3", "--workers", "6", "--aggregator", "median",
            "--adaptive-bits"])
        assert spec.algo.algorithm == "fedavg"
        assert spec.data.case == "noniid2" and spec.data.num_workers == 6
        assert spec.run.rounds == 3
        assert spec.comm.aggregator == "median"
        assert spec.comm.adaptive_bits is True

    def test_mesh_mode_maps_arch_and_steps(self, monkeypatch):
        spec = self._spec_for(monkeypatch, [
            "--mode", "mesh", "--arch", "xlstm-350m", "--steps", "2"])
        assert spec.model.kind == "mesh"
        assert spec.model.name == "xlstm-350m"
        assert spec.run.rounds == 2

    def test_algorithm_flag_applies_to_mesh(self, monkeypatch):
        spec = self._spec_for(monkeypatch, [
            "--mode", "mesh", "--algorithm", "fedavg", "--steps", "1"])
        assert spec.algo.algorithm == "fedavg"

    def test_wrong_kind_flags_fail_fast(self, monkeypatch):
        import sys

        import repro.launch.train as train
        # --rounds on a mesh scenario must error, not silently run the
        # preset's step count
        monkeypatch.setattr(sys, "argv", [
            "train.py", "--scenario", "mesh/smollm-smoke",
            "--rounds", "10"])
        with pytest.raises(SystemExit):
            train.main()
        monkeypatch.setattr(sys, "argv", [
            "train.py", "--mode", "paper", "--steps", "3"])
        with pytest.raises(SystemExit):
            train.main()


class TestGoldenShims:
    def test_paper_shim_matches_pre_refactor_golden(self):
        from repro.launch.train import run_paper_experiment
        rec = run_paper_experiment(
            algorithm="mdsl", case="noniid1", dataset="mnist_like",
            rounds=2, num_workers=4, width_mult=2, local_epochs=1,
            n_local=128, verbose=False)
        rec.pop("round_time_s")
        golden = json.loads(GOLDEN.read_text())
        # the record may only grow by the comm.phy telemetry columns;
        # every pre-refactor field must still be present and bit-equal
        phy_fields = {"airtime_s", "energy_j", "mean_snr_db",
                      "total_airtime_s", "total_energy_j"}
        assert set(rec) - set(golden) <= phy_fields
        assert set(golden) <= set(rec)
        rec = json.loads(json.dumps(rec))  # same float serialization
        for k in golden:
            if k == "comm":
                # CommConfig grew the phy axes; the pre-phy wire fields
                # must keep their exact values
                for ck, cv in golden[k].items():
                    assert rec[k][ck] == cv, f"comm.{ck} drifted"
                continue
            assert rec[k] == golden[k], f"field {k!r} drifted"

    def test_mesh_shim_structure(self):
        from repro.launch.train import run_mesh_training
        rec = run_mesh_training("smollm-360m", steps=1, num_spatial=2,
                                seq_len=16, per_worker_batch=1,
                                verbose=False)
        assert rec["steps"] == 1
        assert rec["bytes_up"][0] == rec["selected"][0] * \
            rec["payload_bytes_per_worker"]


class TestRunnerFacade:
    def test_run_embeds_spec_in_result(self, tmp_path):
        spec = tiny(get_scenario("quickstart"))
        res = run(spec, verbose=False)
        assert res.spec == spec
        p = res.save(tmp_path / "r.json")
        saved = json.loads(p.read_text())
        assert from_dict(saved["spec"]) == spec
        assert saved["metrics"]["final_acc"] == res.record["final_acc"]

    def test_sweep_names_artifacts_by_scenario_and_seed(self, tmp_path):
        spec = tiny(get_scenario("quickstart"))
        results = sweep([spec], seeds=(0, 1), out_dir=tmp_path)
        assert len(results) == 2
        files = sorted(p.name for p in tmp_path.glob("*.json"))
        assert files == ["quickstart__s0.json", "quickstart__s1.json"]
        for p in tmp_path.glob("*.json"):
            saved = json.loads(p.read_text())
            assert saved["spec"]["run"]["seed"] in (0, 1)

    def test_parallel_sweep_matches_serial(self, tmp_path):
        """jobs=2 fans the grid over a process pool: same artifacts,
        same grid-order results, identical metrics (runs are seeded)."""
        spec = tiny(get_scenario("quickstart"))
        serial = sweep([spec], seeds=(0, 1), out_dir=tmp_path / "ser")
        par = sweep([spec], seeds=(0, 1), out_dir=tmp_path / "par",
                    jobs=2)
        assert [r.spec for r in par] == [r.spec for r in serial]
        for a, b in zip(par, serial):
            assert a.record["final_acc"] == b.record["final_acc"]
            assert a.record["bytes_up"] == b.record["bytes_up"]
        assert (sorted(p.name for p in (tmp_path / "par").glob("*.json"))
                == sorted(p.name for p in (tmp_path / "ser").glob("*.json")))

    def test_build_sweep_specs_crosses_axes(self):
        """--sweep x --sweep-axis x --set builds the full grid (the
        paper's 4-algo x 3-case grid is one CLI command)."""
        import argparse

        from repro.launch.train import build_sweep_specs
        args = argparse.Namespace(
            sweep="paper/fig3-iid,paper/fig3-noniid1",
            sweep_axis=["algo.algorithm=fedavg,mdsl"],
            overrides=["run.rounds=1"])
        specs = build_sweep_specs(args)
        assert len(specs) == 4
        assert {(s.data.case, s.algo.algorithm) for s in specs} == {
            ("iid", "fedavg"), ("iid", "mdsl"),
            ("noniid1", "fedavg"), ("noniid1", "mdsl")}
        assert all(s.run.rounds == 1 for s in specs)
        with pytest.raises(ValueError):
            build_sweep_specs(argparse.Namespace(
                sweep="paper/fig3-iid", sweep_axis=["algo.algorithm"],
                overrides=[]))

    def test_sweep_cli_rejects_stray_per_axis_flags(self, capsys):
        """--sweep must fail fast on legacy per-axis flags it would
        otherwise silently drop (same contract as single runs)."""
        import sys
        from unittest import mock

        from repro.launch import train
        argv = ["train", "--sweep", "paper/fig3-iid",
                "--channel", "erasure"]
        with mock.patch.object(sys, "argv", argv):
            with pytest.raises(SystemExit):
                train.main()
        assert "--channel" in capsys.readouterr().err
