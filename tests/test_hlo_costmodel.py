"""Validation of the while-multiplicity-aware HLO cost model against
XLA's own cost_analysis on controlled programs."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_costmodel


def lower_text(fn, *args):
    compiled = jax.jit(fn).lower(*args).compile()
    cost = compiled.cost_analysis()
    # older jax returns one dict per device/computation
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return compiled.as_text(), cost


class TestDotFlops:
    def test_plain_matmul(self):
        x = jnp.ones((64, 128))
        w = jnp.ones((128, 32))
        text, cost = lower_text(lambda x, w: x @ w, x, w)
        rec = hlo_costmodel.analyze(text)
        # XLA counts FMA as 1 flop -> cost_analysis = N*M*K; ours = 2NMK
        assert rec["flops"] == 2 * 64 * 128 * 32

    def test_batched_matmul(self):
        x = jnp.ones((4, 16, 32))
        w = jnp.ones((4, 32, 8))
        text, _ = lower_text(lambda x, w: jnp.einsum("bik,bkj->bij", x, w),
                             x, w)
        rec = hlo_costmodel.analyze(text)
        assert rec["flops"] == 2 * 4 * 16 * 32 * 8


class TestWhileMultiplicity:
    @pytest.mark.parametrize("trips", [4, 8, 17])
    def test_scan_counts_trip_times(self, trips):
        x = jnp.ones((32, 64))
        ws = jnp.ones((trips, 64, 64))

        def scanned(x, ws):
            def body(c, w):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, ws)
            return y.sum()

        text, cost = lower_text(scanned, x, ws)
        rec = hlo_costmodel.analyze(text)
        per_trip = 2 * 32 * 64 * 64
        # the scan dot must be counted `trips` times (allow fori fusion
        # noise of one extra body)
        assert rec["flops"] >= trips * per_trip
        assert rec["flops"] <= (trips + 1) * per_trip
        assert rec["max_while_trip"] >= trips
        # and XLA's own count misses the multiplicity (counts body once):
        xla_flops = float(cost.get("flops", 0.0))
        assert xla_flops * 2 < rec["flops"] * (2 / trips) * 1.5

    def test_scan_matches_unrolled(self):
        trips = 6
        x = jnp.ones((16, 32))
        ws = jnp.ones((trips, 32, 32))

        def scanned(x, ws):
            def body(c, w):
                return c @ w, None
            y, _ = jax.lax.scan(body, x, ws)
            return y.sum()

        def unrolled(x, ws):
            for i in range(trips):
                x = x @ ws[i]
            return x.sum()

        t1, _ = lower_text(scanned, x, ws)
        t2, _ = lower_text(unrolled, x, ws)
        f1 = hlo_costmodel.analyze(t1)["flops"]
        f2 = hlo_costmodel.analyze(t2)["flops"]
        assert f2 == trips * 2 * 16 * 32 * 32
        assert abs(f1 - f2) <= 2 * 16 * 32 * 32  # <= one extra body


class TestHbmBytes:
    def test_traffic_scales_with_while(self):
        x = jnp.ones((128, 128))

        def loop(x, n):
            def body(_, c):
                return jnp.tanh(c * 1.5)
            return jax.lax.fori_loop(0, n, body, x)

        t4, _ = lower_text(lambda x: loop(x, 4), x)
        t16, _ = lower_text(lambda x: loop(x, 16), x)
        b4 = hlo_costmodel.analyze(t4)["hbm_bytes"]
        b16 = hlo_costmodel.analyze(t16)["hbm_bytes"]
        assert b16 > 2 * b4  # traffic grows with trip count


class TestParser:
    def test_parses_real_dryrun_artifact(self):
        import gzip
        from pathlib import Path
        p = Path(__file__).parents[1] / "artifacts" / "dryrun"
        hlos = sorted(p.glob("smollm-360m__train_4k__single.hlo.gz"))
        if not hlos:
            pytest.skip("dry-run artifacts not present")
        text = gzip.open(hlos[0], "rt").read()
        rec = hlo_costmodel.analyze(text)
        assert rec["flops"] > 0
        assert rec["collectives"]["total_bytes"] > 0
        # 8 scanned layer-groups (32 layers / 4-layer groups): the layer
        # while loop must be found
        assert rec["max_while_trip"] >= 4
