"""Per-kernel validation: pallas_call (interpret=True on CPU) vs the
pure-jnp ref.py oracle, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.pso_update import pso_update, pso_update_ref
from repro.kernels.rglru_scan import rglru_scan, rglru_scan_ref

KEY = jax.random.PRNGKey(0)


class TestFlashAttention:
    @pytest.mark.parametrize("B,Sq,Sk,H,K,hd", [
        (2, 128, 128, 4, 2, 64),
        (1, 256, 256, 4, 4, 32),
        (2, 100, 100, 3, 1, 64),    # unpadded + MQA + odd heads
        (1, 64, 256, 2, 2, 128),    # chunked-prefill suffix alignment
        (1, 512, 512, 2, 1, 64),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal_matches_ref(self, B, Sq, Sk, H, K, hd, dtype):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, Sq, H, hd), dtype)
        k = jax.random.normal(ks[1], (B, Sk, K, hd), dtype)
        v = jax.random.normal(ks[2], (B, Sk, K, hd), dtype)
        out = flash_attention(q, k, v, causal=True, interpret=True)
        g = H // K
        kr = jnp.repeat(k.transpose(0, 2, 1, 3), g, 1).reshape(B * H, Sk, hd)
        vr = jnp.repeat(v.transpose(0, 2, 1, 3), g, 1).reshape(B * H, Sk, hd)
        qr = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
        ref = attention_ref(qr, kr, vr, causal=True, q_offset=Sk - Sq)
        ref = ref.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
        tol = 2e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=tol, rtol=tol)

    @pytest.mark.parametrize("window", [16, 64, 200])
    def test_sliding_window(self, window):
        B, S, H, hd = 1, 256, 2, 64
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, S, H, hd))
        k = jax.random.normal(ks[1], (B, S, H, hd))
        v = jax.random.normal(ks[2], (B, S, H, hd))
        out = flash_attention(q, k, v, causal=True, window=window,
                              interpret=True)
        qr = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
        kr = k.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
        vr = v.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
        ref = attention_ref(qr, kr, vr, causal=True, window=window)
        ref = ref.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)

    def test_block_shape_sweep(self):
        """Different BlockSpec tilings give identical results."""
        B, S, H, hd = 1, 256, 2, 64
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, S, H, hd))
        k = jax.random.normal(ks[1], (B, S, H, hd))
        v = jax.random.normal(ks[2], (B, S, H, hd))
        outs = [flash_attention(q, k, v, causal=True, block_q=bq,
                                block_k=bk, interpret=True)
                for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]]
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], atol=1e-5, rtol=1e-5)


class TestRglruScan:
    @pytest.mark.parametrize("B,S,D", [(2, 256, 128), (1, 100, 128),
                                       (3, 512, 256), (1, 7, 128)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, B, S, D, dtype):
        ks = jax.random.split(KEY, 3)
        a = jax.random.uniform(ks[0], (B, S, D), minval=0.5,
                               maxval=0.999).astype(dtype)
        b = (0.1 * jax.random.normal(ks[1], (B, S, D))).astype(dtype)
        h0 = jax.random.normal(ks[2], (B, D)).astype(dtype)
        out, fin = rglru_scan(h0, a, b, interpret=True)
        ref = rglru_scan_ref(h0, a, b)
        tol = 1e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(out, ref, atol=tol, rtol=tol)
        np.testing.assert_allclose(fin, ref[:, -1], atol=tol, rtol=tol)

    def test_block_size_invariance(self):
        B, S, D = 2, 384, 128
        ks = jax.random.split(KEY, 3)
        a = jax.random.uniform(ks[0], (B, S, D), minval=0.8, maxval=0.99)
        b = 0.1 * jax.random.normal(ks[1], (B, S, D))
        h0 = jax.random.normal(ks[2], (B, D))
        outs = [rglru_scan(h0, a, b, block_s=bs, interpret=True)[0]
                for bs in (64, 128, 384)]
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], rtol=1e-6)


class TestPsoUpdateKernel:
    @pytest.mark.parametrize("shapes", [
        [(100,)], [(1000,), (37, 13)], [(8, 128), (5,), (3, 3, 3)],
        [(256 * 128 + 1,)],  # crosses the block boundary
    ])
    @pytest.mark.parametrize("clip", [0.0, 0.5])
    def test_matches_ref(self, shapes, clip):
        ks = jax.random.split(KEY, 5 * len(shapes))
        mk = lambda i: {f"p{j}": jax.random.normal(ks[i * len(shapes) + j],
                                                   s)
                        for j, s in enumerate(shapes)}
        w, v, wl, wg, d = mk(0), mk(1), mk(2), mk(3), mk(4)
        w2, v2 = pso_update(w, v, wl, wg, d, 0.7, 0.2, -0.4, clip=clip,
                            interpret=True)
        coefs = jnp.array([0.7, 0.2, -0.4, clip])
        for key in w:
            wr, vr = pso_update_ref(coefs, w[key], v[key], wl[key],
                                    wg[key], d[key])
            np.testing.assert_allclose(w2[key], wr, atol=1e-6, rtol=1e-5)
            np.testing.assert_allclose(v2[key], vr, atol=1e-6, rtol=1e-5)

    def test_semantics_match_core_pso(self):
        """Kernel == core/pso.py pso_step wiring (delta = -lr*grad)."""
        from repro.core import pso
        from repro.core.pso import PsoCoefficients
        params = {"w": jax.random.normal(KEY, (50,))}
        st = pso.init_worker_state(params)
        st = st._replace(velocity={"w": jnp.ones((50,)) * 0.1},
                         best_params={"w": params["w"] + 0.3})
        gbest = {"w": params["w"] - 0.2}
        grads = {"w": jnp.full((50,), 0.5)}
        coeffs = PsoCoefficients(*(jnp.asarray(x) for x in (0.6, 0.1, 0.2)))
        lr = jnp.asarray(0.05)
        out = pso.pso_step(st, gbest, grads, coeffs, lr)
        delta = {"w": -lr * grads["w"]}
        w2, v2 = pso_update(st.params, st.velocity, st.best_params, gbest,
                            delta, 0.6, 0.1, 0.2, interpret=True)
        np.testing.assert_allclose(w2["w"], out.params["w"], rtol=1e-5)
        np.testing.assert_allclose(v2["w"], out.velocity["w"], rtol=1e-5)
