"""End-to-end behaviour of the M-DSL round engine (Algorithm 1) and the
distributed swarm step: training improves, selection stays within bounds,
comm accounting matches the mask, all four algorithms run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import losses, mdsl, noniid, swarm_dist
from repro.core.pso import PsoHyperParams
from repro.core.swarm_dist import DistSwarmConfig
from repro.data import partition, synthetic
from repro.models import cnn

SPEC = synthetic.MNIST_LIKE


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    C = 8
    data = partition.dirichlet_partition(key, C, 0.5, SPEC, n_local=96,
                                         n_global=192, n_test=256)
    eta = noniid.noniid_degree_from_labels(data.y, data.global_y,
                                           SPEC.num_classes)
    model = cnn.make_cnn5(SPEC.height, SPEC.width, SPEC.channels,
                          SPEC.num_classes, width_mult=4)
    loss_fn = lambda p, x, y: losses.cross_entropy_loss(
        model.apply(p, x), y, SPEC.num_classes)
    return data, eta, model, loss_fn, C


def run_rounds(setup, algorithm, rounds=6):
    data, eta, model, loss_fn, C = setup
    cfg = mdsl.MdslConfig(algorithm=algorithm, local_epochs=2,
                          batch_size=32,
                          hp=PsoHyperParams(learning_rate=0.05,
                                            velocity_clip=0.1))
    state = mdsl.init_state(jax.random.PRNGKey(1), model.init, C, eta)
    n_params = mdsl.count_params(state.global_params)
    history = []
    for r in range(rounds):
        state, m = mdsl.mdsl_round(
            state, data.x, data.y, data.global_x, data.global_y,
            jax.random.PRNGKey(100 + r), loss_fn=loss_fn, eval_fn=loss_fn,
            cfg=cfg, n_params=n_params)
        history.append(m)
    acc = losses.accuracy(model.apply(state.global_params, data.test_x),
                          data.test_y)
    return state, history, float(acc)


@pytest.mark.parametrize("algorithm", ["fedavg", "dsl", "multi_dsl", "mdsl"])
def test_all_algorithms_train(setup, algorithm):
    state, history, acc = run_rounds(setup, algorithm)
    C = setup[4]
    first, last = history[0], history[-1]
    assert bool(jnp.isfinite(last.global_loss))
    # vanilla DSL (single best worker) is seed-flaky at 6 smoke rounds —
    # the very weakness the paper's multi-worker selection addresses (§I);
    # assert learning only for the multi-worker algorithms
    if algorithm != "dsl":
        floor = 0.02 if algorithm == "multi_dsl" else 0.05
        assert acc > 1.0 / SPEC.num_classes + floor, f"{algorithm} acc={acc}"
    for m in history:
        assert 1 <= float(m.selected_count) <= C
        if algorithm == "fedavg":
            assert float(m.selected_count) == C
        if algorithm == "dsl":
            assert float(m.selected_count) == 1


def test_mdsl_beats_single_worker_dsl(setup):
    """The paper's headline claim (Fig. 3 ordering) at smoke scale."""
    _, _, acc_dsl = run_rounds(setup, "dsl")
    _, _, acc_mdsl = run_rounds(setup, "mdsl")
    assert acc_mdsl > acc_dsl


def test_round0_selects_all_workers(setup):
    _, history, _ = run_rounds(setup, "mdsl", rounds=1)
    assert float(history[0].selected_count) == setup[4]


def test_comm_accounting_matches_mask(setup):
    _, history, _ = run_rounds(setup, "mdsl", rounds=4)
    data, eta, model, loss_fn, C = setup
    n = mdsl.count_params(model.init(jax.random.PRNGKey(1)))
    for m in history:
        assert float(m.uploaded_params) == pytest.approx(
            float(m.mask.sum()) * n)
        # paper IV-C: never more than FedAvg's n*C
        assert float(m.uploaded_params) <= n * C


def test_mdsl_uses_eta_in_scores(setup):
    data, eta, model, loss_fn, C = setup
    _, history, _ = run_rounds(setup, "mdsl", rounds=2)
    _, history_md, _ = run_rounds(setup, "multi_dsl", rounds=2)
    # theta differs exactly by the eta term with tau=0.9
    theta_m = history[1].theta
    theta_f = history_md[1].theta
    assert not np.allclose(np.asarray(theta_m), np.asarray(theta_f))


class TestDistSwarm:
    def _setup(self, W=4):
        key = jax.random.PRNGKey(0)
        din, dout = 8, 3

        def init(k):
            k1, k2 = jax.random.split(k)
            return {"w": 0.1 * jax.random.normal(k1, (din, dout)),
                    "b": jnp.zeros((dout,))}

        def loss_fn(p, batch):
            logits = batch["x"] @ p["w"] + p["b"]
            return losses.cross_entropy_loss(logits, batch["y"], dout)

        xs = jax.random.normal(key, (W, 64, din))
        w_true = jax.random.normal(jax.random.fold_in(key, 7), (din, dout))
        ys = jnp.argmax(xs @ w_true, axis=-1)
        batch = {"x": xs, "y": ys}
        eval_batch = {"x": xs[0], "y": ys[0]}
        return init, loss_fn, batch, eval_batch

    def test_train_step_learns_and_selects(self):
        W = 4
        init, loss_fn, batch, eval_batch = self._setup(W)
        cfg = DistSwarmConfig(worker_axes=(), num_spatial=W, local_steps=4,
                              hp=PsoHyperParams(learning_rate=0.3,
                                                velocity_clip=0.05))
        step = jax.jit(swarm_dist.build_train_step(loss_fn, cfg))
        state = swarm_dist.init_state(init(jax.random.PRNGKey(1)), cfg)
        # W>1 without mesh: vmap without spmd name is exercised via W>1 path
        losses_hist = []
        for r in range(12):
            state, info = step(state, batch, eval_batch,
                               jax.random.PRNGKey(50 + r))
            losses_hist.append(float(info.global_loss))
            assert 1 <= float(info.mask.sum()) <= W
        assert losses_hist[-1] < losses_hist[0]

    def test_w1_fsdp_path(self):
        init, loss_fn, batch, eval_batch = self._setup(1)
        cfg = DistSwarmConfig(worker_axes=(), num_spatial=1, local_steps=2)
        step = jax.jit(swarm_dist.build_train_step(loss_fn, cfg))
        state = swarm_dist.init_state(init(jax.random.PRNGKey(1)), cfg)
        state, info = step(state, batch, eval_batch, jax.random.PRNGKey(9))
        assert info.mask.shape == (1,)
        assert bool(jnp.isfinite(info.global_loss))

    def test_fedavg_baseline_step(self):
        W = 4
        init, loss_fn, batch, eval_batch = self._setup(W)
        cfg = DistSwarmConfig(worker_axes=(), num_spatial=W, local_steps=2,
                              hp=PsoHyperParams(learning_rate=0.3))
        step = jax.jit(swarm_dist.fedavg_train_step(loss_fn, cfg))
        state = swarm_dist.init_state(init(jax.random.PRNGKey(1)), cfg)
        l0 = None
        for r in range(8):
            state, info = step(state, batch, eval_batch,
                               jax.random.PRNGKey(60 + r))
            l0 = l0 or float(info.global_loss)
        assert float(info.global_loss) < l0
