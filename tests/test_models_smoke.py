"""Per-architecture smoke tests (required deliverable f): a REDUCED
variant of each assigned architecture (<=2-ish layers, d_model<=512,
<=4 experts) runs one forward + one train-step on CPU with shape and
finiteness assertions."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_arch, list_archs
from repro.core import pso
from repro.models.transformer import Transformer

ARCHS = [a for a in list_archs()]


def make_batch(cfg, key, B=2, S=16):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    batch["labels"] = batch["tokens"]
    if cfg.input_mode == "tokens+prefix":
        batch["prefix"] = 0.1 * jax.random.normal(
            key, (B, cfg.prefix_len, cfg.d_model))
    if cfg.encoder_layers:
        batch["frames"] = 0.1 * jax.random.normal(
            key, (B, cfg.encoder_memory_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_constraints(arch):
    cfg = get_arch(arch).reduced()
    assert cfg.num_layers <= 4
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = dataclasses.replace(get_arch(arch).reduced(), dtype="float32")
    model = Transformer(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = make_batch(cfg, key)

    logits, aux = model.forward(params, batch)
    B, S = batch["tokens"].shape
    S_out = S + (cfg.prefix_len if cfg.input_mode == "tokens+prefix" else 0)
    assert logits.shape == (B, S_out, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"

    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(jnp.sum(g.astype(jnp.float32) ** 2)
                for g in jax.tree.leaves(grads))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0

    # one SGD train step moves the loss
    new_params = pso.sgd_step(params, grads, jnp.asarray(0.05))
    loss2 = model.loss(new_params, batch)
    assert bool(jnp.isfinite(loss2))
    assert float(loss2) < float(loss) + 1e-3


@pytest.mark.parametrize("arch", ["smollm-360m", "starcoder2-7b",
                                  "recurrentgemma-9b", "xlstm-350m",
                                  "qwen3-moe-30b-a3b",
                                  "seamless-m4t-large-v2", "llava-next-34b"])
def test_decode_matches_forward(arch):
    """Prefill + token-by-token decode reproduces teacher-forcing logits."""
    cfg = dataclasses.replace(get_arch(arch).reduced(), dtype="float32")
    if cfg.num_experts:
        cfg = dataclasses.replace(
            cfg, moe_capacity_factor=float(cfg.num_experts) /
            cfg.experts_per_token)  # dropless => exact match
    model = Transformer(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    B, S, P = 2, 20, 6
    batch = make_batch(cfg, key, B=B, S=S)
    memory = (model.encode(params, batch["frames"])
              if cfg.encoder_layers else None)
    full_logits, _ = model.forward(params, batch)
    off = cfg.prefix_len if cfg.input_mode == "tokens+prefix" else 0

    cache = model.init_cache(B, S + off, memory=memory, params=params)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :P]
    lg, cache = model.prefill(params, pre, cache)
    errs = [float(jnp.abs(lg[:, 0] - full_logits[:, off + P - 1]).max())]
    for t in range(P, S):
        lg, cache = model.decode_step(params, batch["tokens"][:, t:t + 1],
                                      cache)
        errs.append(float(jnp.abs(lg[:, 0] - full_logits[:, off + t]).max()))
    assert max(errs) < 2e-4, f"decode drift {max(errs)}"


def test_sliding_window_ring_buffer():
    """starcoder2-family ring cache: decode far past the window matches
    teacher forcing."""
    cfg = dataclasses.replace(get_arch("starcoder2-7b").reduced(),
                              dtype="float32", window_size=8)
    model = Transformer(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    B, S, P = 1, 40, 4  # decode 36 tokens with window 8 (ring wraps 4x)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full_logits, _ = model.forward(params, {"tokens": tokens,
                                            "labels": tokens})
    cache = model.init_cache(B, S)
    # ring buffer: cache size == window
    assert cache["groups"]["b0"]["temporal"]["k"].shape[2] == cfg.window_size
    lg, cache = model.prefill(params, {"tokens": tokens[:, :P]}, cache)
    errs = [float(jnp.abs(lg[:, 0] - full_logits[:, P - 1]).max())]
    for t in range(P, S):
        lg, cache = model.decode_step(params, tokens[:, t:t + 1], cache)
        errs.append(float(jnp.abs(lg[:, 0] - full_logits[:, t]).max()))
    assert max(errs) < 2e-4, f"ring cache drift {max(errs)}"


def test_param_count_analytic_close_to_actual():
    for arch in ["smollm-360m", "xlstm-350m", "qwen3-moe-30b-a3b"]:
        cfg = dataclasses.replace(get_arch(arch).reduced(), dtype="float32")
        model = Transformer(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        actual = sum(s.size for s in jax.tree.leaves(shapes))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.15, (
            arch, actual, analytic)
