"""Expert-parallel shard_map MoE dispatch vs the dense GSPMD reference.

The equivalence test runs in a subprocess with 8 host devices (the
device count is locked at first jax init, so it cannot run in-process)
and dropless capacities, where EP and the sort-based dispatch must agree
to fp tolerance.
"""
import subprocess
import sys
import textwrap
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe_ep import _pack

ROOT = Path(__file__).resolve().parents[1]


def _subprocess_env():
    """Inherit the environment (JAX_PLATFORMS=cpu etc. — a bare env
    makes jax probe for TPUs for minutes) but pin PYTHONPATH and drop
    any outer XLA_FLAGS so the script controls the device count."""
    import os

    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    env["PYTHONPATH"] = str(ROOT / "src")
    return env


class TestPack:
    def test_pack_roundtrip_no_drops(self):
        ids = jnp.array([2, 0, 1, 2, 0, 1, 1, 3])
        vals = jnp.arange(8.0)[:, None] * jnp.ones((8, 3))
        bufs, slot = _pack(ids, 4, 3, {"x": vals})
        flat = jnp.concatenate(
            [bufs["x"].reshape(-1, 3), jnp.zeros((1, 3))], axis=0)
        np.testing.assert_allclose(flat[slot], vals)  # full inversion

    def test_pack_drops_overflow(self):
        ids = jnp.zeros((5,), jnp.int32)  # all to bin 0, cap 2
        vals = jnp.arange(5.0)[:, None]
        bufs, slot = _pack(ids, 2, 2, {"x": vals})
        assert int((slot == 2 * 2).sum()) == 3  # 3 dropped
        kept = bufs["x"].reshape(-1)[:2]
        assert set(np.asarray(kept)) <= set(range(5))

    def test_pack_valid_mask(self):
        ids = jnp.array([0, 1, 0, 1])
        valid = jnp.array([True, False, True, True])
        bufs, slot = _pack(ids, 2, 2, {"x": jnp.ones((4, 1))}, valid=valid)
        assert int(slot[1]) == 2 * 2  # invalid -> sentinel
        assert float(bufs["x"].sum()) == 3.0


EQUIV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.configs.base import get_arch
    from repro.models import moe
    from repro.sharding.rules import ShardingRules, use_rules

    cfg = get_arch("qwen3-moe-30b-a3b").reduced()
    cfg = dataclasses.replace(cfg, num_experts=8, experts_per_token=2,
                              moe_capacity_factor=float(8 // 2))  # dropless
    key = jax.random.PRNGKey(0)
    params = moe.moe_init(key, cfg)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model),
                                jnp.float32).astype(jnp.bfloat16)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rules_ep = ShardingRules(batch="data", seq=None, embed=None,
                             expert="data", expert_mlp="model",
                             embed_fsdp=None, mlp="model", moe_ep=True)
    rules_ref = ShardingRules(rules_ep, moe_ep=False)

    outs = {}
    for name, rules in (("ep", rules_ep), ("ref", rules_ref)):
        def f(p, x):
            with use_rules(rules, mesh):
                return moe.moe_apply(p, x, cfg)
        # jax.set_mesh is new-API; old jax uses the Mesh context manager
        with (jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh):
            y, aux = jax.jit(f)(params, x)
        outs[name] = (np.asarray(y, np.float32), float(aux))

    y_ep, aux_ep = outs["ep"]
    y_ref, aux_ref = outs["ref"]
    err = np.abs(y_ep - y_ref).max()
    print("MAXERR", err, "AUX", abs(aux_ep - aux_ref))
    assert err < 5e-2, err                       # bf16 accumulation order
    assert abs(aux_ep - aux_ref) < 1e-3
    print("EP-EQUIV-OK")
""")


@pytest.mark.slow
def test_ep_matches_dense_dispatch_8dev():
    res = subprocess.run(
        [sys.executable, "-c", EQUIV_SCRIPT],
        env=_subprocess_env(),
        capture_output=True, text=True, timeout=600)
    assert "EP-EQUIV-OK" in res.stdout, res.stdout + res.stderr


@pytest.mark.slow
def test_ep_grad_flows_8dev():
    script = EQUIV_SCRIPT.replace(
        'assert err < 5e-2, err',
        'assert err < 5e-2, err\n'
        '    # grad through the EP path\n')
    grad_script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_arch
        from repro.models import moe
        from repro.sharding.rules import ShardingRules, use_rules

        cfg = get_arch("qwen3-moe-30b-a3b").reduced()
        cfg = dataclasses.replace(cfg, num_experts=8, experts_per_token=2,
                                  moe_capacity_factor=4.0)
        params = moe.moe_init(jax.random.PRNGKey(0), cfg)
        x = 0.1 * jax.random.normal(jax.random.PRNGKey(1),
                                    (8, 16, cfg.d_model))
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rules = ShardingRules(batch="data", expert="data",
                              expert_mlp="model", mlp="model", moe_ep=True)

        def loss(p, x):
            with use_rules(rules, mesh):
                y, aux = moe.moe_apply(p, x, cfg)
            return (y.astype(jnp.float32) ** 2).mean() + 0.01 * aux

        with (jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh):
            g = jax.jit(jax.grad(loss))(params, x)
        total = sum(float(jnp.abs(l.astype(jnp.float32)).sum())
                    for l in jax.tree.leaves(g))
        assert total > 0 and np.isfinite(total)
        wi_g = float(jnp.abs(g["wi"].astype(jnp.float32)).sum())
        assert wi_g > 0  # grads reach the expert weights through a2a
        print("EP-GRAD-OK", total)
    """)
    res = subprocess.run(
        [sys.executable, "-c", grad_script],
        env=_subprocess_env(),
        capture_output=True, text=True, timeout=600)
    assert "EP-GRAD-OK" in res.stdout, res.stdout + res.stderr
