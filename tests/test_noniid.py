"""Unit + property tests for the non-i.i.d. degree metric (paper §II)."""
import hypothesis as hp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import noniid


def _rand_dist(rng, L):
    p = rng.random(L) + 1e-6
    return p / p.sum()


class TestWasserstein:
    def test_identical_is_zero(self):
        p = jnp.array([0.2, 0.3, 0.5])
        assert float(noniid.wasserstein_1d(p, p)) == pytest.approx(0.0)

    def test_disjoint_extremes(self):
        # all mass at 0 vs all mass at L-1: W1 = L-1
        L = 10
        p = jnp.zeros(L).at[0].set(1.0)
        q = jnp.zeros(L).at[L - 1].set(1.0)
        assert float(noniid.wasserstein_1d(p, q)) == pytest.approx(L - 1)

    @hp.given(st.integers(2, 12), st.integers(0, 2**31 - 1))
    @hp.settings(max_examples=30, deadline=None)
    def test_symmetry_and_nonneg(self, L, seed):
        rng = np.random.default_rng(seed)
        p, q = jnp.array(_rand_dist(rng, L)), jnp.array(_rand_dist(rng, L))
        w_pq = float(noniid.wasserstein_1d(p, q))
        w_qp = float(noniid.wasserstein_1d(q, p))
        assert w_pq >= 0
        assert w_pq == pytest.approx(w_qp, abs=1e-5)

    @hp.given(st.integers(2, 10), st.integers(0, 2**31 - 1),
              st.integers(0, 2**31 - 1))
    @hp.settings(max_examples=30, deadline=None)
    def test_triangle_inequality(self, L, s1, s2):
        rng1, rng2 = np.random.default_rng(s1), np.random.default_rng(s2)
        p = jnp.array(_rand_dist(rng1, L))
        q = jnp.array(_rand_dist(rng2, L))
        r = jnp.full((L,), 1.0 / L)
        w = lambda a, b: float(noniid.wasserstein_1d(a, b))
        assert w(p, q) <= w(p, r) + w(r, q) + 1e-5


class TestEta:
    def test_normalized_range(self):
        key = jax.random.PRNGKey(0)
        labels = jax.random.randint(key, (8, 64), 0, 10)
        glabels = jax.random.randint(key, (256,), 0, 10)
        eta = noniid.noniid_degree_from_labels(labels, glabels, 10)
        assert eta.shape == (8,)
        assert float(eta.min()) == pytest.approx(0.0, abs=1e-6)
        assert float(eta.max()) == pytest.approx(1.0, abs=1e-6)

    def test_label_ratio(self):
        local = jnp.array([5.0, 0.0, 3.0, 0.0])
        glob = jnp.array([10.0, 10.0, 10.0, 10.0])
        assert float(noniid.label_ratio(local, glob)) == pytest.approx(0.5)

    def test_skewed_worker_has_larger_wd(self):
        """A one-class worker is farther from uniform than a uniform one."""
        g = jax.random.randint(jax.random.PRNGKey(1), (1000,), 0, 10)
        uniform_worker = jax.random.randint(jax.random.PRNGKey(2), (512,), 0, 10)
        skewed_worker = jnp.zeros((512,), jnp.int32)
        _, wd_u = noniid.noniid_features(uniform_worker, g, 10)
        _, wd_s = noniid.noniid_features(skewed_worker, g, 10)
        assert float(wd_s) > float(wd_u)


class TestFit:
    def test_recovers_linear_coefficients(self):
        rng = np.random.default_rng(0)
        n = 200
        ratios = rng.random(n)
        wds = rng.random(n) * 3
        acc = 0.4 * ratios - 0.1 * wds + 0.3 + rng.normal(0, 1e-3, n)
        coeffs, r2_tr, r2_te = noniid.fit_eta_coefficients(ratios, wds, acc)
        assert coeffs.beta1 == pytest.approx(0.4, abs=0.01)
        assert coeffs.beta2 == pytest.approx(-0.1, abs=0.01)
        assert coeffs.phi == pytest.approx(0.3, abs=0.01)
        assert r2_tr > 0.99 and r2_te > 0.99
