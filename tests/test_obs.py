"""repro.obs: event model round-trips, sink behavior, stage tracing,
and the stream/artifact bit-equality contract.

The load-bearing guarantee is tested end-to-end on both engines: an
obs-enabled 3-round run's RoundEvents must carry exactly the artifact's
per-round metric history, bit-equal after one JSON round trip (the
runner builds ONE row dict and feeds both) — and turning obs on must
not perturb the numerics relative to an obs-off run of the same seed.
"""
import json
from pathlib import Path

import hypothesis as hp
import hypothesis.strategies as st
import pytest

from repro.experiments import (SCHEMA_VERSION, get_scenario, load_result,
                               override, run, sweep, to_dict)
from repro.obs import (EVENT_TYPES, NULL, CsvSink, Emitter, FanoutSink,
                       JsonlSink, KernelEvent, RingBufferSink, RoundEvent,
                       RunEnd, RunStart, StageEvent, StageTracer, SweepEvent,
                       follow_jsonl, merge_streams, new_run_id, parse,
                       parse_line, read_events)
from repro.obs import monitor as obs_monitor
from repro.obs import trace as obs_trace

TINY_PAPER = ("data.num_workers=4", "data.n_local=64", "run.rounds=3",
              "model.width_mult=2", "algo.local_epochs=1")
TINY_MESH = ("data.num_workers=2", "model.seq_len=16",
             "model.per_worker_batch=1", "run.rounds=3")

# the RoundPipeline stages whose spans must appear on every obs stream
PIPELINE_STAGES = {"LocalUpdate", "ScoreSelect", "Uplink", "Aggregate",
                   "Downlink", "BestTracking"}


def _obs_spec(scenario: str, obs_dir: Path, *extra: str):
    spec = get_scenario(scenario)
    ovr = TINY_PAPER if spec.model.kind == "paper" else TINY_MESH
    return override(spec, *ovr, "run.obs.enabled=true",
                    f"run.obs.dir={obs_dir}", *extra)


@pytest.fixture(scope="module")
def paper_obs(tmp_path_factory):
    """One obs-enabled 3-round paper run, shared across tests."""
    obs_dir = tmp_path_factory.mktemp("paper_obs")
    res = run(_obs_spec("quickstart", obs_dir, "run.obs.csv=true"),
              verbose=False)
    return res, read_events(res.events_path)


@pytest.fixture(scope="module")
def mesh_obs(tmp_path_factory):
    obs_dir = tmp_path_factory.mktemp("mesh_obs")
    res = run(_obs_spec("mesh/smollm-smoke", obs_dir), verbose=False)
    return res, read_events(res.events_path)


class TestEventModel:
    @pytest.mark.parametrize("cls", sorted(EVENT_TYPES.values(),
                                           key=lambda c: c.kind))
    def test_default_round_trip(self, cls):
        ev = cls(run_id="r", t_s=1.5)
        assert parse_line(ev.to_json()) == ev

    def test_populated_round_trip(self):
        ev = RoundEvent(run_id="r", t_s=0.25, round=7,
                        metrics={"acc": 0.125, "selected": 3.0})
        back = parse(json.loads(ev.to_json()))
        assert back == ev
        assert back.metrics["acc"] == 0.125

    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError, match="unknown event kind"):
            parse({"kind": "telemetry", "run_id": "r"})

    def test_unknown_field_rejected(self):
        with pytest.raises(ValueError, match="gpu_watts"):
            parse({"kind": "round", "run_id": "r", "t_s": 0.0,
                   "round": 0, "metrics": {}, "gpu_watts": 42})

    @hp.given(st.lists(st.floats(min_value=-1e9, max_value=1e9),
                       min_size=1, max_size=12))
    def test_metric_floats_survive_stream_bit_equal(self, vals):
        """Any float payload must cross the JSONL boundary bit-equal —
        the property the artifact/stream equality contract rests on."""
        metrics = {f"m{i}": v for i, v in enumerate(vals)}
        back = parse_line(RoundEvent(run_id="r", metrics=metrics).to_json())
        assert back.metrics == metrics

    def test_new_run_id_distinct_and_greppable(self):
        a, b = new_run_id("quickstart"), new_run_id("quickstart")
        assert a != b
        assert a.startswith("quickstart__")
        assert "/" not in new_run_id("mesh/smollm-smoke")


class TestSinks:
    def test_jsonl_round_trip(self, tmp_path):
        p = tmp_path / "s.jsonl"
        em = Emitter("rid", JsonlSink(p))
        em.run_start(scenario="q", seed=0)
        em.round(0, {"acc": 0.5})
        em.run_end(rounds=1, totals={"acc": 0.5})
        em.close()
        evs = read_events(p)
        assert [e.kind for e in evs] == ["run_start", "round", "run_end"]
        assert all(e.run_id == "rid" for e in evs)
        assert [e.t_s for e in evs] == sorted(e.t_s for e in evs)

    def test_jsonl_rotation(self, tmp_path):
        p = tmp_path / "s.jsonl"
        sink = JsonlSink(p, rotate_bytes=200)
        em = Emitter("rid", sink)
        for t in range(20):
            em.round(t, {"acc": 0.1})
        em.close()
        assert p.with_name("s.jsonl.1").exists()
        # the live file may have just rotated away; if present it's capped
        if p.exists():
            assert p.stat().st_size <= 400

    def test_csv_rounds_only_fixed_columns(self, tmp_path):
        p = tmp_path / "s.csv"
        em = Emitter("rid", CsvSink(p))
        em.run_start(scenario="q")          # ignored by the CSV view
        em.round(0, {"acc": 0.5, "loss": 2.0})
        em.round(1, {"acc": 0.6, "loss": 1.5, "extra": 9.0})
        em.close()
        lines = p.read_text().strip().splitlines()
        assert lines[0] == "run_id,round,t_s,acc,loss"
        assert len(lines) == 3
        assert lines[1].startswith("rid,0,")

    def test_ring_buffer_caps(self):
        sink = RingBufferSink(capacity=3)
        em = Emitter("rid", sink)
        for t in range(10):
            em.round(t, {})
        assert [e.round for e in sink.events] == [7, 8, 9]

    def test_fanout_tees_and_proxies_path(self, tmp_path):
        ring = RingBufferSink()
        jsonl = JsonlSink(tmp_path / "s.jsonl")
        em = Emitter("rid", FanoutSink(ring, jsonl))
        em.round(0, {"acc": 0.5})
        em.close()
        assert em.path == str(tmp_path / "s.jsonl")
        assert len(ring.events) == len(read_events(em.path)) == 1

    def test_merge_streams_regroups_by_run_id(self, tmp_path):
        # two interleaved producers, one file each (the sweep-pool shape)
        for rid in ("a", "b"):
            em = Emitter(rid, JsonlSink(tmp_path / f"{rid}.jsonl"))
            em.round(0, {})
            em.round(1, {})
            em.close()
        runs = merge_streams(sorted(tmp_path.glob("*.jsonl")))
        assert set(runs) == {"a", "b"}
        for evs in runs.values():
            assert [e.round for e in evs] == [0, 1]
            assert [e.t_s for e in evs] == sorted(e.t_s for e in evs)

    def test_follow_jsonl_stops_on_run_end(self, tmp_path):
        p = tmp_path / "s.jsonl"
        em = Emitter("rid", JsonlSink(p))
        em.round(0, {})
        em.run_end(rounds=1)
        em.close()
        evs = list(follow_jsonl(p, poll_s=0.01, timeout_s=2.0))
        assert [e.kind for e in evs] == ["round", "run_end"]

    def test_follow_jsonl_times_out_without_growth(self, tmp_path):
        p = tmp_path / "s.jsonl"
        em = Emitter("rid", JsonlSink(p))
        em.round(0, {})
        em.close()
        evs = list(follow_jsonl(p, poll_s=0.01, timeout_s=0.1))
        assert [e.kind for e in evs] == ["round"]


class TestTracing:
    def test_stage_span_is_shared_nullcontext_when_uninstalled(self):
        assert obs_trace.current() is None
        assert obs_trace.stage_span("Uplink") is obs_trace._NOOP
        assert obs_trace.stage_span("Downlink") is obs_trace._NOOP

    def test_spans_emit_stage_events(self):
        ring = RingBufferSink()
        tracer = StageTracer(Emitter("rid", ring), phase="trace")
        with obs_trace.activated(tracer):
            with obs_trace.stage_span("Uplink"):
                pass
            obs_trace.note_kernel("quant_pack", backend="cpu",
                                  interpret=True, bits=4)
        assert obs_trace.current() is None
        stage, kernel = ring.events
        assert isinstance(stage, StageEvent)
        assert (stage.stage, stage.phase) == ("Uplink", "trace")
        assert stage.dur_s >= 0.0
        assert isinstance(kernel, KernelEvent)
        assert kernel.info == {"bits": 4}

    def test_activated_restores_previous_tracer(self):
        outer = StageTracer(Emitter("o", RingBufferSink()))
        inner = StageTracer(Emitter("i", RingBufferSink()))
        with obs_trace.activated(outer):
            with obs_trace.activated(inner):
                assert obs_trace.current() is inner
            assert obs_trace.current() is outer
        assert obs_trace.current() is None

    def test_null_emitter_span_is_reusable(self):
        with NULL.span("Step"):
            with NULL.span("Step"):   # nullcontext must be reentrant
                pass
        assert NULL.path is None and not NULL.active


class TestRunStreamIntegrity:
    """The acceptance contract: stream == artifact, bit-equal, and obs
    must not perturb the run."""

    @pytest.mark.parametrize("fixture", ["paper_obs", "mesh_obs"])
    def test_round_events_bit_equal_to_artifact(self, fixture, request):
        res, evs = request.getfixturevalue(fixture)
        art = json.loads(json.dumps(res.to_dict()))   # the saved form
        rounds = [e for e in evs if isinstance(e, RoundEvent)]
        assert [e.round for e in rounds] == [0, 1, 2]
        hist = art["metrics"]
        # per-round histories are the length-`rounds` lists; the rest of
        # the artifact is post-run summary scalars (final_acc, totals...)
        per_round = {k for k, v in hist.items()
                     if isinstance(v, list) and len(v) == len(rounds)}
        assert per_round == set(rounds[0].metrics)
        for ev in rounds:
            for k, v in ev.metrics.items():
                if k.endswith("_time_s"):
                    continue  # wall-clock, not part of the contract
                assert hist[k][ev.round] == v, (ev.round, k)

    @pytest.mark.parametrize("fixture", ["paper_obs", "mesh_obs"])
    def test_stream_shape_and_stage_coverage(self, fixture, request):
        res, evs = request.getfixturevalue(fixture)
        assert isinstance(evs[0], RunStart)
        assert isinstance(evs[-1], RunEnd)
        assert evs[-1].status == "ok" and evs[-1].rounds == 3
        assert evs[0].rounds == 3 and evs[0].n_params > 0
        assert evs[0].spec == json.loads(json.dumps(to_dict(res.spec)))
        traced = {e.stage for e in evs
                  if isinstance(e, StageEvent) and e.phase == "trace"}
        assert PIPELINE_STAGES <= traced
        host = {e.stage for e in evs
                if isinstance(e, StageEvent) and e.phase == "host"}
        assert "Step" in host
        assert all(e.run_id == evs[0].run_id for e in evs)
        assert [e.t_s for e in evs] == sorted(e.t_s for e in evs)

    def test_obs_does_not_perturb_metrics(self, paper_obs, tmp_path):
        res_on, _ = paper_obs
        spec_off = override(res_on.spec, "run.obs.enabled=false")
        res_off = run(spec_off, verbose=False)
        on, off = res_on.record, res_off.record
        assert set(on) == set(off)
        for k in on:
            if k.endswith("_time_s"):
                continue
            assert on[k] == off[k], k

    def test_csv_mirror_matches_stream(self, paper_obs):
        res, evs = paper_obs
        csv_path = Path(res.events_path).with_suffix(".csv")
        lines = csv_path.read_text().strip().splitlines()
        rounds = [e for e in evs if isinstance(e, RoundEvent)]
        assert len(lines) == 1 + len(rounds)
        assert lines[0].split(",")[:3] == ["run_id", "round", "t_s"]
        assert set(lines[0].split(",")[3:]) == set(rounds[0].metrics)


class TestArtifactSchema:
    def test_saved_artifact_declares_schema(self, paper_obs, tmp_path):
        res, _ = paper_obs
        d = res.to_dict()
        assert d["schema"] == SCHEMA_VERSION == 2
        assert d["events"] == res.events_path
        p = tmp_path / "r.json"
        p.write_text(json.dumps(d))
        assert load_result(p)["metrics"] == d["metrics"]

    def test_loader_defaults_missing_schema_to_v1(self, tmp_path):
        p = tmp_path / "v1.json"
        p.write_text(json.dumps({"spec": {}, "metrics": {"acc": [0.1]}}))
        loaded = load_result(p)
        assert loaded["schema"] == 1

    def test_loader_fails_loudly_on_unknown_schema(self, tmp_path):
        p = tmp_path / "v9.json"
        p.write_text(json.dumps({"schema": 9, "spec": {}, "metrics": {}}))
        with pytest.raises(ValueError, match="schema"):
            load_result(p)

    def test_loader_rejects_non_artifact(self, tmp_path):
        p = tmp_path / "bad.json"
        p.write_text(json.dumps({"schema": 2, "hello": "world"}))
        with pytest.raises(ValueError):
            load_result(p)


class TestMonitor:
    def test_render_finished_run(self, paper_obs):
        res, evs = paper_obs
        out = obs_monitor.render(evs)
        assert "quickstart" in out
        assert "rounds 3/3" in out
        for stage in PIPELINE_STAGES:
            assert stage in out
        assert "end: status=ok" in out

    def test_render_empty_stream(self):
        assert "no run_start" in obs_monitor.render([])

    def test_resolve_stream_picks_newest_in_dir(self, tmp_path):
        old, new = tmp_path / "a.jsonl", tmp_path / "b.jsonl"
        old.write_text("")
        new.write_text("")
        import os
        os.utime(old, (1, 1))
        assert obs_monitor.resolve_stream(tmp_path) == new
        assert obs_monitor.resolve_stream(new) == new

    def test_main_renders_non_follow(self, paper_obs, capsys):
        res, _ = paper_obs
        obs_monitor.main([res.events_path])
        out = capsys.readouterr().out
        assert "quickstart" in out and "rounds 3/3" in out


class TestSweepObs:
    def test_sweep_stderr_reports_wall_and_events(self, tmp_path, capsys):
        spec = _obs_spec("quickstart", tmp_path / "obs", "run.rounds=1")
        results = sweep([spec], seeds=(0,), out_dir=tmp_path / "art")
        err = capsys.readouterr().err
        assert "[sweep] quickstart s0:" in err
        assert "wall=" in err
        assert "events=" in err
        # sweep-level stream: one SweepEvent per cell + a run_end
        streams = [p for p in (tmp_path / "obs").glob("*.jsonl")
                   if "sweep__" in p.name]
        assert len(streams) == 1
        evs = read_events(streams[0])
        cells = [e for e in evs if isinstance(e, SweepEvent)]
        assert len(cells) == 1 and cells[0].cell == "quickstart"
        assert cells[0].status == "ok" and cells[0].wall_s > 0
        assert cells[0].events == results[0].events_path
        assert isinstance(evs[-1], RunEnd)
        assert "cells (1):" in obs_monitor.render(evs)

    def test_sweep_obs_off_emits_no_streams(self, tmp_path, capsys):
        spec = override(get_scenario("quickstart"), *TINY_PAPER,
                        "run.rounds=1")
        sweep([spec], seeds=(0,), out_dir=tmp_path / "art")
        err = capsys.readouterr().err
        assert "[sweep] quickstart s0:" in err and "wall=" in err
        assert "events=" not in err
