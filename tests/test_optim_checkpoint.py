"""Unit + property tests for the optim/ and checkpoint/ substrates."""
import hypothesis as hp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.checkpoint import CheckpointManager, restore_pytree, save_pytree
from repro.optim import schedules

KEY = jax.random.PRNGKey(0)


def quad_problem(dim=8):
    """Convex quadratic: loss(p) = ||p - target||^2."""
    target = jax.random.normal(KEY, (dim,))
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    p0 = {"w": jnp.zeros(dim)}
    return loss, p0, target


class TestOptimizers:
    @pytest.mark.parametrize("make", [
        lambda: optim.sgd(0.1),
        lambda: optim.momentum_sgd(0.05, beta=0.9),
        lambda: optim.momentum_sgd(0.05, beta=0.9, nesterov=True),
        lambda: optim.adamw(0.1),
    ])
    def test_converges_on_quadratic(self, make):
        loss, p, target = quad_problem()
        opt = make()
        state = opt.init(p)
        for step in range(200):
            g = jax.grad(loss)(p)
            upd, state = opt.update(g, state, p, step)
            p = optim.apply_updates(p, upd)
        assert float(loss(p)) < 1e-2

    def test_weight_decay_shrinks(self):
        opt = optim.sgd(0.1, weight_decay=0.5)
        p = {"w": jnp.ones(4)}
        upd, _ = opt.update({"w": jnp.zeros(4)}, opt.init(p), p, 0)
        assert np.all(np.asarray(upd["w"]) < 0)

    def test_pso_hybrid_interface(self):
        loss, p, target = quad_problem()
        opt = optim.pso_hybrid(0.05, velocity_clip=1.0)
        state = opt.init(p)
        # seed the swarm attractors at the optimum: PSO pull + gradient
        # must make clear progress (the per-step N(0,1) cognitive/social
        # coefficients keep the iterate jittering around the optimum, so
        # assert improvement rather than convergence)
        state = state._replace(best_params={"w": target},
                               gbest_params={"w": target})
        l0 = float(loss(p))
        for step in range(300):
            g = jax.grad(loss)(p)
            upd, state = opt.update(g, state, p, step)
            p = optim.apply_updates(p, upd)
        assert float(loss(p)) < 0.5 * l0

    def test_clip_by_global_norm(self):
        t = {"a": jnp.full((4,), 10.0), "b": jnp.full((3,), -10.0)}
        c = optim.clip_by_global_norm(t, 1.0)
        assert float(optim.global_norm(c)) <= 1.0 + 1e-5

    @hp.given(st.floats(1e-4, 1.0), st.integers(1, 50))
    @hp.settings(max_examples=20, deadline=None)
    def test_step_decay_monotone(self, lr, every):
        sched = schedules.step_decay(lr, gamma=0.5, every=every)
        vals = [float(sched(jnp.asarray(s))) for s in range(0, 120, 7)]
        assert all(a >= b - 1e-12 for a, b in zip(vals, vals[1:]))
        assert abs(vals[0] - lr) < 1e-6 * max(lr, 1.0)  # f32 schedule

    def test_warmup_cosine_shape(self):
        sched = schedules.warmup_cosine(1.0, warmup_steps=10, total_steps=100)
        assert float(sched(jnp.asarray(0))) == 0.0
        assert abs(float(sched(jnp.asarray(10))) - 1.0) < 1e-6
        assert float(sched(jnp.asarray(100))) < 0.2


class TestCheckpoint:
    def test_roundtrip_nested(self, tmp_path):
        tree = {"layer": {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
                          "b": np.zeros(3, np.float32)},
                "step": np.asarray(7)}
        p = tmp_path / "ck.npz"
        save_pytree(p, tree, metadata={"note": "x"})
        back = restore_pytree(p)
        np.testing.assert_array_equal(back["layer"]["w"], tree["layer"]["w"])
        np.testing.assert_array_equal(back["step"], 7)

    def test_restore_into_template_casts(self, tmp_path):
        tree = {"w": jnp.ones((4,), jnp.float32)}
        p = tmp_path / "ck.npz"
        save_pytree(p, tree)
        tmpl = {"w": jnp.zeros((4,), jnp.bfloat16)}
        back = restore_pytree(p, like=tmpl)
        assert back["w"].dtype == jnp.bfloat16

    def test_template_mismatch_raises(self, tmp_path):
        save_pytree(tmp_path / "ck.npz", {"w": jnp.ones(3)})
        with pytest.raises(ValueError):
            restore_pytree(tmp_path / "ck.npz", like={"other": jnp.ones(3)})

    def test_manager_retention_and_latest(self, tmp_path):
        mgr = CheckpointManager(tmp_path, max_to_keep=2)
        for s in [1, 2, 3, 4]:
            mgr.save(s, {"w": jnp.full((2,), float(s))})
        assert mgr.all_steps() == [3, 4]
        step, tree = mgr.restore()
        assert step == 4
        np.testing.assert_allclose(tree["w"], 4.0)

    @hp.given(st.lists(st.integers(1, 40), min_size=1, max_size=6,
                       unique=True))
    @hp.settings(max_examples=10, deadline=None)
    def test_manager_keeps_newest(self, tmp_path_factory, steps):
        tmp = tmp_path_factory.mktemp("ck")
        mgr = CheckpointManager(tmp, max_to_keep=3)
        for s in sorted(steps):
            mgr.save(s, {"w": jnp.zeros(1)})
        assert mgr.all_steps() == sorted(steps)[-3:]
