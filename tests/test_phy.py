"""comm.phy — per-worker physical layer: Rayleigh fading statistics,
LinkModel composability (erasure x AWGN x outage), SNR->rate airtime
and energy accounting, N-tier adaptive bit allocation, and the
unit-gain-fading ≡ ideal equivalence through the full round pipeline."""
import hypothesis as hp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import budget, channel, phy
from repro.comm.budget import CommConfig
from repro.core import rounds

KEY = jax.random.PRNGKey(0)


class TestPhyState:
    def test_init_is_unit_gain(self):
        cfg = CommConfig(fading="rayleigh")
        st_ = phy.init_state(cfg, 8)
        np.testing.assert_array_equal(np.asarray(st_.h_re), 1.0)
        np.testing.assert_array_equal(np.asarray(st_.h_im), 0.0)
        np.testing.assert_allclose(np.asarray(st_.snr_db), cfg.snr_db,
                                   rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(st_.age), 0)

    def test_pathloss_profile_spreads_snr(self):
        cfg = CommConfig(pathloss_spread_db=12.0)
        st_ = phy.init_state(cfg, 4)
        snr = np.asarray(st_.snr_db)
        np.testing.assert_allclose(snr, cfg.snr_db - np.asarray(
            [0.0, 4.0, 8.0, 12.0]), rtol=1e-5)

    def test_evolve_noop_without_fading(self):
        cfg = CommConfig()
        st_ = phy.init_state(cfg, 4)
        assert phy.evolve(cfg, st_, KEY) is st_

    def test_static_channel_at_rho_one(self):
        cfg = CommConfig(fading="rayleigh", doppler_rho=1.0)
        st_ = phy.init_state(cfg, 4)
        out = phy.evolve(cfg, st_, KEY)
        np.testing.assert_array_equal(np.asarray(out.h_re),
                                      np.asarray(st_.h_re))
        np.testing.assert_array_equal(np.asarray(out.h_im),
                                      np.asarray(st_.h_im))

    @hp.given(st.floats(min_value=0.1, max_value=0.95), st.integers(0, 3))
    @hp.settings(max_examples=12, deadline=None)
    def test_fading_gain_unbiased(self, rho, seed):
        """E|h_t|^2 = 1 at every round (unit-gain init + Gauss-Markov
        with unit innovation power), so the fading adds no systematic
        uplink gain or attenuation."""
        C = 512
        cfg = CommConfig(fading="rayleigh", doppler_rho=float(rho))
        st_ = phy.init_state(cfg, C)
        key = jax.random.PRNGKey(seed)
        gains = []
        for t in range(40):
            key, k = jax.random.split(key)
            st_ = phy.evolve(cfg, st_, k)
            gains.append(np.asarray(st_.h_re) ** 2
                         + np.asarray(st_.h_im) ** 2)
        assert np.mean(gains) == pytest.approx(1.0, abs=0.08)

    def test_age_tracks_delivery(self):
        cfg = CommConfig()
        st_ = phy.init_state(cfg, 3)
        st_ = phy.advance_age(st_, jnp.asarray([1.0, 0.0, 0.0]))
        st_ = phy.advance_age(st_, jnp.asarray([0.0, 1.0, 0.0]))
        np.testing.assert_array_equal(np.asarray(st_.age), [1, 0, 2])


class TestLinkModel:
    def test_legacy_enum_decomposition(self):
        ideal = phy.link_model(CommConfig())
        assert ideal.drop_prob == 0.0 and not ideal.awgn
        era = phy.link_model(CommConfig(channel="erasure", drop_prob=0.3))
        assert era.drop_prob == 0.3 and not era.awgn
        awgn = phy.link_model(CommConfig(channel="awgn"))
        assert awgn.drop_prob == 0.0 and awgn.awgn
        both = phy.link_model(CommConfig(channel="composite",
                                         drop_prob=0.3))
        assert both.drop_prob == 0.3 and both.awgn

    def test_composite_applies_erasure_and_awgn_in_one_round(self):
        """Regression for the old enum's non-composability: with
        channel="composite", drop_prob>0 AND a finite snr_db both act
        on the same round — packets drop AND the survivors' aggregate
        is noisy (erasure_mask used to silently no-op unless
        channel == "erasure")."""
        cfg = CommConfig(channel="composite", drop_prob=0.5, snr_db=10.0)
        g = {"x": jnp.zeros(64)}
        wire = {"x": jax.random.normal(KEY, (8, 64))}
        mask = jnp.ones(8)
        saw_drop = False
        key = KEY
        for _ in range(20):
            key, k = jax.random.split(key)
            out, mask_eff = channel.receive(cfg, g, wire, mask, k)
            surv = np.asarray(mask_eff).astype(bool)
            if 0 < surv.sum() < 8:
                saw_drop = True
                clean = np.asarray(wire["x"])[surv].mean(axis=0)
                noise = np.abs(np.asarray(out["x"]) - clean)
                assert noise.max() > 1e-4   # AWGN hit the same round
        assert saw_drop                     # erasure hit too

    def test_outage_drops_faded_workers(self):
        cfg = CommConfig(fading="rayleigh", outage_snr_db=0.0, snr_db=10.0)
        mask = jnp.ones(4)
        snr = jnp.asarray([5.0, -3.0, 12.0, -0.1])
        out = phy.delivery_mask(cfg, mask, KEY, snr_db=snr)
        np.testing.assert_array_equal(np.asarray(out), [1.0, 0.0, 1.0, 0.0])

    def test_outage_composes_with_packet_erasure(self):
        cfg = CommConfig(channel="composite", drop_prob=0.5,
                         fading="rayleigh", outage_snr_db=0.0)
        C = 64
        mask = jnp.ones(C)
        # first half above the outage cut, second half below
        snr = jnp.concatenate([jnp.full((C // 2,), 10.0),
                               jnp.full((C // 2,), -10.0)])
        out = np.asarray(phy.delivery_mask(cfg, mask, KEY, snr_db=snr))
        np.testing.assert_array_equal(out[C // 2:], 0.0)  # outage filter
        assert 0 < out[: C // 2].sum() < C // 2           # erasure filter

    def test_outage_erasure_composes_with_robust_aggregators(self):
        """Satellite: SNR-outage delivery loss flows into the robust
        Eq.-7 order statistics exactly like packet erasure — the
        median/trimmed mean run over the delivered subset only."""
        C, n = 9, 16
        d = jax.random.normal(KEY, (C, n))
        snr = jnp.asarray([10.0] * 5 + [-10.0] * 4)   # last 4 in outage
        for agg in ("median", "trimmed_mean"):
            cfg = CommConfig(aggregator=agg, fading="rayleigh",
                             outage_snr_db=0.0, trim_ratio=0.2)
            g = {"x": jnp.zeros(n)}
            out, mask_eff = channel.receive(cfg, g, {"x": d}, jnp.ones(C),
                                            KEY, snr_db=snr)
            np.testing.assert_array_equal(np.asarray(mask_eff),
                                          [1.0] * 5 + [0.0] * 4)
            dd = np.sort(np.asarray(d)[:5], axis=0)
            if agg == "median":
                want = dd[2]
            else:
                t = int(0.2 * 5)
                want = dd[t:5 - t].mean(axis=0)
            np.testing.assert_allclose(np.asarray(out["x"]), want,
                                       rtol=1e-5, atol=1e-6)

    def test_per_worker_awgn_tracks_individual_snr(self):
        """With fading, distortion is per-upload at each worker's own
        SNR: a deep-faded worker's decode is much noisier than a
        well-faded one's."""
        C, n = 2, 4096
        d = jnp.ones((C, n))
        snr = jnp.asarray([30.0, -10.0])
        sigma = phy.noise_sigma_per_worker(d, snr)
        assert float(sigma[0, 0]) < 0.1 < float(sigma[1, 0])
        # and the mean-path aggregate with only the GOOD worker selected
        # is far cleaner than with only the bad one
        cfg = CommConfig(channel="awgn", fading="rayleigh")
        g = {"x": jnp.zeros(n)}
        errs = []
        for sel in ([1.0, 0.0], [0.0, 1.0]):
            out, _ = channel.receive(cfg, g, {"x": d}, jnp.asarray(sel),
                                     KEY, snr_db=snr)
            errs.append(float(jnp.abs(out["x"] - 1.0).mean()))
        assert errs[0] < 0.1 < errs[1]


class TestValidation:
    def test_snr_rank_needs_per_worker_snr(self):
        with pytest.raises(ValueError):
            CommConfig(adaptive_bits=True, tier_rank="snr").validate()
        CommConfig(adaptive_bits=True, tier_rank="snr",
                   fading="rayleigh").validate()
        CommConfig(adaptive_bits=True, tier_rank="snr",
                   pathloss_spread_db=6.0).validate()

    def test_outage_needs_per_worker_snr(self):
        """A static fleet-wide SNR makes the outage cut an all-or-
        nothing blackout — rejected at the config layer so direct
        engine users get the same protection as spec users."""
        with pytest.raises(ValueError):
            CommConfig(outage_snr_db=25.0).validate()
        CommConfig(outage_snr_db=0.0, fading="rayleigh").validate()

    def test_new_enum_fields_validated(self):
        for bad in (dict(fading="rician"), dict(rate_model="polar"),
                    dict(tier_rank="random"), dict(doppler_rho=1.5),
                    dict(num_tiers=1), dict(bandwidth_hz=0.0),
                    dict(tx_power_w=-1.0), dict(coding_gap_db=-1.0)):
            with pytest.raises(ValueError):
                CommConfig(**bad).validate()


class TestRateModel:
    def test_rate_monotone_in_snr(self):
        cfg = CommConfig()
        snrs = jnp.asarray([-10.0, 0.0, 10.0, 20.0, 30.0])
        rates = np.asarray(budget.rate_bps(cfg, snrs))
        assert np.all(np.diff(rates) > 0)
        assert np.all(rates > 0)

    def test_coding_gap_costs_rate(self):
        snr = jnp.asarray([10.0])
        ideal = budget.rate_bps(CommConfig(coding_gap_db=0.0), snr)
        gapped = budget.rate_bps(CommConfig(coding_gap_db=3.0), snr)
        assert float(gapped[0]) < float(ideal[0])

    def test_airtime_and_energy_monotone_in_snr(self):
        """Satellite: a better channel drains less airtime and energy
        for the same payload."""
        tree = {"x": jnp.zeros(1000)}
        mask = jnp.ones(4)
        prev_airtime, prev_energy = np.inf, np.inf
        for snr in (0.0, 10.0, 20.0):
            rec = budget.round_record(CommConfig(), tree, 4, mask, mask,
                                      snr_db=jnp.full((4,), snr))
            assert 0 < float(rec.airtime_s) < prev_airtime
            assert 0 < float(rec.energy_j) < prev_energy
            prev_airtime = float(rec.airtime_s)
            prev_energy = float(rec.energy_j)

    def test_energy_scales_with_tx_power(self):
        tree = {"x": jnp.zeros(1000)}
        mask = jnp.ones(4)
        lo = budget.round_record(CommConfig(tx_power_w=0.1), tree, 4, mask,
                                 mask)
        hi = budget.round_record(CommConfig(tx_power_w=0.2), tree, 4, mask,
                                 mask)
        assert float(hi.energy_j) == pytest.approx(2 * float(lo.energy_j),
                                                   rel=1e-5)
        assert float(hi.airtime_s) == pytest.approx(float(lo.airtime_s),
                                                    rel=1e-6)

    def test_lost_packets_still_charge_airtime(self):
        tree = {"x": jnp.zeros(1000)}
        mask = jnp.ones(4)
        none_lost = budget.round_record(CommConfig(), tree, 4, mask, mask)
        all_lost = budget.round_record(CommConfig(), tree, 4, mask,
                                       jnp.zeros(4))
        assert float(all_lost.airtime_s) == float(none_lost.airtime_s)


class TestNTierMasks:
    @pytest.mark.parametrize("C,T", [(4, 2), (5, 2), (7, 3), (12, 3),
                                     (9, 4)])
    def test_tier_masks_partition_fleet(self, C, T):
        """Satellite: the N tier masks partition the worker set — every
        worker lands on exactly one tier, group sizes follow the
        ceil(C t / T) boundaries."""
        cfg = CommConfig(adaptive_bits=True, num_tiers=T)
        theta = jax.random.normal(jax.random.fold_in(KEY, C * T), (C,))
        tiers, tier_idx = rounds.tier_masks(cfg, theta)
        assert len(tiers) == min(T, 3)  # identity->int8->int4 floor
        idx = np.asarray(tier_idx)
        assert idx.min() == 0 and idx.max() == len(tiers) - 1
        counts = np.bincount(idx, minlength=len(tiers))
        assert counts.sum() == C            # a partition: each worker once
        bounds = [-(-C * t // len(tiers)) for t in range(len(tiers) + 1)]
        np.testing.assert_array_equal(counts, np.diff(bounds))

    def test_two_tier_matches_legacy_split(self):
        cfg = CommConfig(compressor="int8", adaptive_bits=True)
        theta = jnp.asarray([3.0, 0.5, 2.0, 1.0])  # best: 1, 3, 2, 0
        tiers, idx = rounds.tier_masks(cfg, theta)
        assert [t.compressor for t in tiers] == ["int8", "int4"]
        np.testing.assert_array_equal(np.asarray(idx), [1, 0, 1, 0])

    def test_three_tier_chain_from_identity(self):
        cfg = CommConfig(adaptive_bits=True, num_tiers=3)
        tiers = budget.uplink_tiers(cfg)
        assert [t.compressor for t in tiers] == ["identity", "int8", "int4"]

    def test_snr_rank_gives_bits_to_good_channels(self):
        cfg = CommConfig(adaptive_bits=True, num_tiers=3, tier_rank="snr",
                         fading="rayleigh")
        theta = jnp.asarray([0.0, 1.0, 2.0, 3.0, 4.0, 5.0])
        snr = jnp.asarray([-5.0, 20.0, 3.0, 15.0, -1.0, 8.0])
        _, idx = rounds.tier_masks(cfg, theta, snr_db=snr)
        idx = np.asarray(idx)
        # best SNR workers (1, 3) on tier 0; worst (0, 4) on tier 2
        np.testing.assert_array_equal(idx, [2, 0, 1, 0, 2, 1])

    def test_snr_rank_falls_back_to_score_without_phy(self):
        cfg = CommConfig(adaptive_bits=True, tier_rank="snr",
                         fading="rayleigh")
        theta = jnp.asarray([3.0, 0.5, 2.0, 1.0])
        _, idx = rounds.tier_masks(cfg, theta, snr_db=None)
        np.testing.assert_array_equal(np.asarray(idx), [1, 0, 1, 0])

    def test_n_tier_bytes_decrease_with_more_tiers(self):
        tree = {"x": jnp.zeros(100000)}
        mask = jnp.ones(9)
        theta = jnp.arange(9, dtype=jnp.float32)
        recs = []
        for T in (2, 3):
            cfg = CommConfig(adaptive_bits=True, num_tiers=T)
            _, idx = rounds.tier_masks(cfg, theta)
            recs.append(budget.round_record(cfg, tree, 9, mask, mask,
                                            tier_idx=idx))
        assert float(recs[1].bytes_up) < float(recs[0].bytes_up)


def _phy_paper_scenario(comm, rounds_n=3):
    """The test_rounds paper scenario, parameterized by CommConfig."""
    from test_rounds import _paper_scenario
    return _paper_scenario(comm=comm, rounds_n=rounds_n)


class TestPipelineEquivalence:
    def test_unit_gain_fading_bit_equal_to_ideal(self):
        """Satellite: fading="rayleigh" with doppler_rho=1 keeps the
        unit-gain init forever — SNRs collapse to the shared snr_db and
        an ideal channel produces bit-identical global params (the phy
        state rides along without touching the values)."""
        base, m0 = _phy_paper_scenario(CommConfig())
        faded, m1 = _phy_paper_scenario(
            CommConfig(fading="rayleigh", doppler_rho=1.0))
        for a, b in zip(jax.tree.leaves(base.global_params),
                        jax.tree.leaves(faded.global_params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert float(m0.global_loss) == float(m1.global_loss)
        assert float(m0.bytes_up) == float(m1.bytes_up)

    def test_rayleigh_run_is_finite_and_reports_energy(self):
        state, m = _phy_paper_scenario(
            CommConfig(channel="awgn", snr_db=10.0, fading="rayleigh",
                       doppler_rho=0.9))
        for leaf in jax.tree.leaves(state.global_params):
            assert bool(jnp.isfinite(leaf).all())
        assert float(m.airtime_s) > 0 and float(m.energy_j) > 0
        assert np.isfinite(float(m.mean_snr_db))

    def test_fading_evolves_phy_state_in_engine(self):
        state, _ = _phy_paper_scenario(
            CommConfig(channel="awgn", fading="rayleigh", doppler_rho=0.5))
        h2 = (np.asarray(state.phy.h_re) ** 2
              + np.asarray(state.phy.h_im) ** 2)
        assert not np.allclose(h2, 1.0)    # gains actually moved

    def test_outage_run_ages_undelivered_workers(self):
        state, m = _phy_paper_scenario(
            CommConfig(channel="awgn", snr_db=3.0, fading="rayleigh",
                       doppler_rho=0.3, outage_snr_db=0.0), rounds_n=4)
        assert float(m.delivered) <= float(m.selected_count)
        assert int(np.asarray(state.phy.age).max()) >= 0
        for leaf in jax.tree.leaves(state.global_params):
            assert bool(jnp.isfinite(leaf).all())
