"""Population/cohort engine (core/population.py + the runner wrapper).

Pins the three load-bearing properties:
  * degenerate anchor — population == cohort_size under the uniform
    policy reproduces the legacy full-fleet run bit-for-bit (the same
    guarantee the golden pins give the engines, extended through the
    gather/reseat/scatter seam);
  * sampling — every policy returns a valid K-subset of [0, P), and
    the weighted policies order as documented (score_weighted prefers
    low Eq.-5 theta, snr_aware prefers high last-known SNR);
  * lazy fading — the closed-form rho^Δ catch-up matches the per-round
    Gauss-Markov recursion's coefficients and preserves unit power.
"""
import hypothesis as hp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import phy as comm_phy
from repro.comm.budget import CommConfig
from repro.core import population as pop
from repro.experiments.registry import get_scenario
from repro.experiments.runner import build, run
from repro.experiments.spec import override

KEY = jax.random.PRNGKey(0)

# record keys whose histories must match exactly between a legacy run
# and its degenerate population-wrapped twin
_EXACT_KEYS = ("acc", "global_loss", "selected", "delivered",
               "uploaded_params", "bytes_up", "bytes_down", "airtime_s",
               "energy_j", "mean_snr_db")


def _records_bitwise(spec):
    legacy = run(spec, verbose=False).record
    K = spec.data.num_workers
    wrapped = run(override(spec, f"fleet.population={K}",
                           f"fleet.cohort_size={K}"),
                  verbose=False).record
    for k in _EXACT_KEYS:
        assert legacy[k] == wrapped[k], (k, legacy[k], wrapped[k])
    # the wrapped run reports its fleet shape + the identity cohorts
    assert wrapped["population"] == K
    assert wrapped["cohort_size"] == K
    assert wrapped["cohort"] == [list(range(K))] * spec.run.rounds
    assert "cohort" not in legacy


class TestDegenerateBitIdentity:
    def test_quickstart(self):
        """Default wire (ideal channel, no fading): the reseat mask is
        all-False and the table round-trips the phy rows bitwise."""
        _records_bitwise(override(get_scenario("quickstart"),
                                  "run.rounds=2"))

    def test_phy_heavy_wire(self):
        """Rayleigh fading + composite channel + outage + int8 uplink:
        the lag-0 guards must pass the evolved channel state through the
        table untouched — every stochastic wire stage stays on the
        legacy key chain."""
        spec = override(get_scenario("rayleigh-outage"),
                        "data.num_workers=4", "data.n_local=64",
                        "model.width_mult=2", "algo.local_epochs=1",
                        "run.rounds=2", "comm.compressor=int8")
        _records_bitwise(spec)


class TestSampling:
    def _table(self, P, comm=CommConfig()):
        return pop.init_table(comm, P)

    @hp.given(st.integers(2, 200), st.integers(1, 16),
              st.sampled_from(pop.COHORT_POLICIES), st.integers(0, 2**20))
    @hp.settings(max_examples=20, deadline=None)
    def test_valid_k_subset(self, P, K, policy, seed):
        hp.assume(K <= P)
        idx = pop.sample_cohort(self._table(P), K, policy,
                                jax.random.fold_in(KEY, seed))
        a = np.asarray(idx)
        assert a.shape == (K,) and a.dtype == np.int32
        assert len(set(a.tolist())) == K
        assert (a >= 0).all() and (a < P).all()

    def test_degenerate_identity_no_draw(self):
        idx = pop.sample_cohort(self._table(16), 16, "uniform", KEY)
        np.testing.assert_array_equal(np.asarray(idx), np.arange(16))

    def _membership_counts(self, table, policy, K, draws=64):
        lo = hi = 0
        P = table.score.shape[0]
        for s in range(draws):
            idx = np.asarray(pop.sample_cohort(
                table, K, policy, jax.random.fold_in(KEY, s)))
            lo += int((idx < P // 2).sum())
            hi += int((idx >= P // 2).sum())
        return lo, hi

    def test_score_weighted_prefers_low_theta(self):
        """Devices whose last Eq.-5 theta was low (= better) must win
        seats more often than the high-theta half."""
        P = 64
        t = self._table(P)
        score = jnp.where(jnp.arange(P) < P // 2, 0.0, 10.0)
        t = t._replace(score=score,
                       last_seen=jnp.zeros((P,), jnp.int32))
        lo, hi = self._membership_counts(t, "score_weighted", K=8)
        assert lo > 2 * hi, (lo, hi)

    def test_score_weighted_unseen_degrades_to_uniform(self):
        """Round 0 (nothing seen): the standardized logits are all zero,
        so the draw is uniform — both halves get seats."""
        lo, hi = self._membership_counts(self._table(64), "score_weighted",
                                         K=8)
        assert lo > 0 and hi > 0
        assert 0.5 < lo / hi < 2.0, (lo, hi)

    def test_snr_aware_prefers_high_snr(self):
        P = 64
        t = self._table(P)
        snr = jnp.where(jnp.arange(P) < P // 2, -10.0, 10.0)
        t = t._replace(phy=t.phy._replace(snr_db=snr.astype(jnp.float32)))
        lo, hi = self._membership_counts(t, "snr_aware", K=8)
        assert hi > 2 * lo, (lo, hi)


class TestLazyFading:
    _COMM = CommConfig(fading="rayleigh", doppler_rho=0.9)

    def test_zero_lag_is_identity(self):
        rho_d, innov = comm_phy.lazy_fading_coeffs(
            self._COMM, jnp.zeros((4,), jnp.int32))
        np.testing.assert_array_equal(np.asarray(rho_d), 1.0)
        np.testing.assert_array_equal(np.asarray(innov), 0.0)

    def test_single_step_matches_evolve(self):
        """Δ=1 reproduces the per-round recursion's (rho, sqrt(1-rho²))
        exactly — the same coefficients `phy.evolve` applies."""
        rho = self._COMM.doppler_rho
        rho_d, innov = comm_phy.lazy_fading_coeffs(
            self._COMM, jnp.ones((1,), jnp.int32))
        np.testing.assert_allclose(float(rho_d[0]), rho, rtol=1e-6)
        np.testing.assert_allclose(float(innov[0]),
                                   np.sqrt(1.0 - rho * rho), rtol=1e-6)

    @hp.given(st.integers(0, 500), st.floats(0.0, 1.0))
    @hp.settings(max_examples=20, deadline=None)
    def test_unit_power_preserved(self, lag, rho):
        """rho_d² + innov² = 1 for every Δ: catching up keeps E|h|² = 1
        (the closed form telescopes the variance exactly)."""
        cfg = CommConfig(fading="rayleigh", doppler_rho=rho)
        rho_d, innov = comm_phy.lazy_fading_coeffs(
            cfg, jnp.asarray([lag], jnp.int32))
        np.testing.assert_allclose(
            float(rho_d[0]) ** 2 + float(innov[0]) ** 2, 1.0, atol=1e-5)

    def test_gather_lag0_passthrough_bitwise(self):
        """A row whose stored state is current (lag 0) re-enters the
        cohort bit-identical — the degenerate anchor's key guard."""
        P = 8
        table = pop.init_table(self._COMM, P)
        # pretend round 0 just scattered: markers at 0, entering round 1
        table = table._replace(
            last_seen=jnp.zeros((P,), jnp.int32),
            last_evolved=jnp.zeros((P,), jnp.int32))
        idx = jnp.arange(P, dtype=jnp.int32)
        got = pop.gather_phy(self._COMM, table, idx,
                             jnp.int32(1), KEY)
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(table.phy)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_gather_advances_age_by_idle_rounds(self):
        comm = CommConfig()      # fading none: pure age arithmetic
        table = pop.init_table(comm, 4)
        table = table._replace(
            last_seen=jnp.asarray([0, 2, 4, 4], jnp.int32),
            phy=table.phy._replace(age=jnp.asarray([1, 0, 3, 0],
                                                   jnp.int32)))
        got = pop.gather_phy(comm, table, jnp.arange(4, dtype=jnp.int32),
                             jnp.int32(5), KEY)
        np.testing.assert_array_equal(np.asarray(got.age), [5, 2, 3, 0])


class TestScatterRoundtrip:
    def test_scatter_then_gather_roundtrips(self):
        """What a cohort writes back is exactly what it reads out next
        round (lag 0), for a non-identity cohort."""
        comm = CommConfig(fading="rayleigh", doppler_rho=0.8)
        P, K = 32, 4
        table = pop.init_table(comm, P)
        idx = jnp.asarray([3, 17, 8, 29], jnp.int32)
        k1, k2 = jax.random.split(KEY)
        phy = comm_phy.PhyState(
            h_re=jax.random.normal(k1, (K,)),
            h_im=jax.random.normal(k2, (K,)),
            pathloss_db=table.phy.pathloss_db[idx],
            snr_db=jnp.asarray([1.0, 2.0, 3.0, 4.0], jnp.float32),
            age=jnp.asarray([0, 1, 0, 2], jnp.int32))
        theta = jnp.asarray([0.1, 0.2, 0.3, 0.4], jnp.float32)
        efn = jnp.asarray([1.0, 0.0, 2.0, 0.5], jnp.float32)
        t2 = pop.scatter_round(table, idx, phy, theta, efn, jnp.int32(3))
        got = pop.gather_phy(comm, t2, idx, jnp.int32(4), KEY)
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(phy)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(t2.score[idx]),
                                      np.asarray(theta))
        np.testing.assert_array_equal(np.asarray(t2.ef_norm[idx]),
                                      np.asarray(efn))
        # untouched devices keep their init rows
        rest = np.setdiff1d(np.arange(P), np.asarray(idx))
        assert (np.asarray(t2.last_seen)[rest] == -1).all()

    def test_residual_norms(self):
        res = {"w": jnp.asarray([[3.0, 4.0], [0.0, 0.0]]),
               "b": jnp.asarray([[0.0], [12.0]])}
        got = pop.residual_norms(res)
        np.testing.assert_allclose(np.asarray(got), [5.0, 12.0],
                                   rtol=1e-6)


class TestTableFootprint:
    def test_o_p_scalars_only(self):
        """The 1M-device registry is nine (P,) columns — 36 B/device,
        36 MB total — never an O(P) model pytree."""
        specs = pop.table_specs(1_000_000)
        leaves = jax.tree.leaves(specs)
        assert len(leaves) == 9
        assert all(s.shape == (1_000_000,) for s in leaves)
        total = sum(s.size * s.dtype.itemsize for s in leaves)
        assert total == 36_000_000
        small = pop.init_table(CommConfig(), 128)
        assert pop.table_bytes(small) == 128 * 36


class TestMeshPopulationSpecs:
    def test_population_specs_shard_over_workers(self):
        from jax.sharding import Mesh

        from repro.launch.steps import population_specs
        dev = np.array(jax.devices()[:1]).reshape(1, 1)
        mesh = Mesh(dev, ("data", "model"))
        specs, shardings, meta = population_specs(
            CommConfig(), 10_000, mesh, ("data",))
        assert meta["population"] == 10_000
        assert meta["table_bytes"] == 10_000 * 36
        assert meta["bytes_per_shard"] == meta["table_bytes"]  # 1 device
        for s, sh in zip(jax.tree.leaves(specs), jax.tree.leaves(shardings)):
            assert s.shape == (10_000,)
            assert sh.spec == jax.sharding.PartitionSpec("data")


class TestSpecValidation:
    def test_cohort_size_must_match_num_workers(self):
        spec = override(get_scenario("quickstart"), "fleet.population=100",
                        "fleet.cohort_size=4")
        with pytest.raises(ValueError, match="cohort_size"):
            spec.validate()

    def test_population_must_cover_cohort(self):
        spec = override(get_scenario("quickstart"), "fleet.population=4")
        with pytest.raises(ValueError, match="population"):
            spec.validate()

    def test_mesh_specs_reject_population(self):
        spec = override(get_scenario("mesh/smollm-smoke"),
                        "fleet.population=100")
        with pytest.raises(ValueError, match="mesh"):
            spec.validate()

    def test_byzantine_bound_names_cohort_not_population(self):
        """A huge population cannot dilute the Byzantine bound: what
        matters is the K cohort seats the adversaries can flood."""
        spec = override(get_scenario("quickstart"), "fleet.population=1000",
                        "comm.byzantine=8")     # == K: all seats hostile
        with pytest.raises(ValueError, match=r"K=8.*P=1000"):
            spec.validate()


class TestSampledFleetRun:
    def test_small_population_run_end_to_end(self):
        """P=64 > K=8 with the score policy: finite metrics, distinct
        cohorts over rounds, table telemetry in the record."""
        spec = override(get_scenario("quickstart"), "fleet.population=64",
                        "fleet.cohort_size=8",
                        "fleet.cohort_policy=score_weighted",
                        "run.rounds=3")
        rec = run(spec, verbose=False).record
        assert np.isfinite(rec["global_loss"]).all()
        assert np.isfinite(rec["acc"]).all()
        cohorts = rec["cohort"]
        assert len(cohorts) == 3
        for c in cohorts:
            assert len(c) == 8 and len(set(c)) == 8
            assert all(0 <= i < 64 for i in c)
        assert rec["population"] == 64

    def test_build_exposes_table(self):
        spec = override(get_scenario("quickstart"), "fleet.population=64",
                        "fleet.cohort_size=8")
        prep = build(spec)
        assert prep.aux["population"] == 64
        assert prep.aux["table_bytes"] == 64 * 36
        assert prep.state.table.score.shape == (64,)
        np.testing.assert_array_equal(np.asarray(prep.state.cohort),
                                      np.arange(8))
