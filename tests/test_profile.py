"""The attribution profiler: multiplicities and term attribution."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_costmodel, profile


def test_multiplicities_weight_scan_bodies():
    x = jnp.ones((32, 64))
    ws = jnp.ones((5, 64, 64))

    def scanned(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    text = jax.jit(scanned).lower(x, ws).compile().as_text()
    comps, entry = hlo_costmodel.parse_hlo(text)
    mult = profile.computation_multiplicities(comps, entry)
    assert max(mult.values()) >= 5  # the scan body runs 5x


@pytest.mark.parametrize("term", ["memory", "flops"])
def test_attribution_sums_match_analyze(term):
    x = jnp.ones((16, 32))
    ws = jnp.ones((3, 32, 32))

    def f(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    text = jax.jit(f).lower(x, ws).compile().as_text()
    rows = profile.attribute(text, term)
    total = sum(v for v, _, _ in rows)
    rec = hlo_costmodel.analyze(text)
    ref = rec["flops"] if term == "flops" else rec["hbm_bytes"]
    assert total == pytest.approx(ref, rel=1e-6)


def test_dry_run_artifact_attribution():
    import gzip
    from pathlib import Path
    p = Path(__file__).parents[1] / "artifacts" / "dryrun" / \
        "smollm-360m__train_4k__single.hlo.gz"
    if not p.exists():
        pytest.skip("dry-run artifacts not present")
    rows = profile.attribute(gzip.open(p, "rt").read(), "collective")
    assert rows and rows[0][0] > 0
