"""Eq. 5-10 semantics: PSO update, local/global bests, selection rule,
Eq.-7 aggregation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pso, selection
from repro.core.pso import PsoCoefficients, PsoHyperParams


def tiny_params(seed=0, n=7):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (n,)),
            "b": jax.random.normal(jax.random.fold_in(k, 1), (3, 2))}


class TestPsoStep:
    def test_matches_manual_eq8(self):
        p = tiny_params()
        st = pso.init_worker_state(p)
        gbest = jax.tree.map(lambda x: x + 1.0, p)
        grads = jax.tree.map(jnp.ones_like, p)
        coeffs = PsoCoefficients(c0=jnp.asarray(0.5), c1=jnp.asarray(0.2),
                                 c2=jnp.asarray(-0.3), )
        lr = jnp.asarray(0.1)
        new = pso.pso_step(st, gbest, grads, coeffs, lr)
        # v0 = 0, wl = w  =>  v' = c2*(wg - w) - lr*g
        for key in ("w",):
            expect = 0.5 * 0.0 + 0.2 * 0.0 + (-0.3) * 1.0 - 0.1 * 1.0
            np.testing.assert_allclose(new.velocity[key],
                                       jnp.full_like(p[key], expect),
                                       rtol=1e-6)
            np.testing.assert_allclose(new.params[key], p[key] + expect,
                                       rtol=1e-6)

    def test_velocity_clip(self):
        p = tiny_params()
        st = pso.init_worker_state(p)
        gbest = jax.tree.map(lambda x: x + 100.0, p)
        grads = jax.tree.map(jnp.zeros_like, p)
        coeffs = PsoCoefficients(*(jnp.asarray(v) for v in (0.0, 0.0, 1.0)))
        hp = PsoHyperParams(velocity_clip=0.5)
        new = pso.pso_step(st, gbest, grads, coeffs, jnp.asarray(0.1), hp)
        assert float(jnp.abs(new.velocity["w"]).max()) <= 0.5 + 1e-6


class TestBests:
    def test_local_best_improves_only(self):
        st = pso.init_worker_state(tiny_params())
        st = pso.update_local_best(st, jnp.asarray(1.0))
        assert float(st.best_loss) == 1.0
        moved = st._replace(params=jax.tree.map(lambda x: x + 1, st.params))
        worse = pso.update_local_best(moved, jnp.asarray(2.0))
        assert float(worse.best_loss) == 1.0  # kept old best
        np.testing.assert_allclose(worse.best_params["w"], st.params["w"])
        better = pso.update_local_best(moved, jnp.asarray(0.5))
        assert float(better.best_loss) == 0.5
        np.testing.assert_allclose(better.best_params["w"],
                                   moved.params["w"])

    def test_global_best_eq10(self):
        g = pso.init_global_best(tiny_params())
        g = pso.update_global_best(g, tiny_params(1), jnp.asarray(3.0))
        g2 = pso.update_global_best(g, tiny_params(2), jnp.asarray(5.0))
        np.testing.assert_allclose(g2.params["w"], tiny_params(1)["w"])
        g3 = pso.update_global_best(g2, tiny_params(3), jnp.asarray(1.0))
        np.testing.assert_allclose(g3.params["w"], tiny_params(3)["w"])


class TestSelection:
    def test_threshold_rule_eq6(self):
        st = selection.SelectionState(prev_theta_mean=jnp.asarray(1.0))
        theta = jnp.array([0.5, 1.0, 1.5, 0.9])
        mask, nxt = selection.select_workers(theta, st)
        np.testing.assert_array_equal(mask, [1, 1, 0, 1])
        assert float(nxt.prev_theta_mean) == pytest.approx(float(theta.mean()))

    def test_round0_selects_all(self):
        st = selection.init_selection_state()
        theta = jnp.array([10.0, 20.0, 30.0])
        mask, _ = selection.select_workers(theta, st)
        assert float(mask.sum()) == 3

    def test_fallback_selects_best(self):
        st = selection.SelectionState(prev_theta_mean=jnp.asarray(0.0))
        theta = jnp.array([2.0, 1.0, 3.0])
        mask, _ = selection.select_workers(theta, st)
        np.testing.assert_array_equal(mask, [0, 1, 0])

    def test_aggregation_eq7(self):
        C = 4
        g = {"w": jnp.zeros((3,))}
        prev = {"w": jnp.zeros((C, 3))}
        new = {"w": jnp.arange(C * 3, dtype=jnp.float32).reshape(C, 3)}
        mask = jnp.array([1.0, 0.0, 1.0, 0.0])
        out = selection.aggregate_global(g, new, prev, mask)
        expect = (new["w"][0] + new["w"][2]) / 2
        np.testing.assert_allclose(out["w"], expect)

    def test_comm_cost(self):
        mask = jnp.array([1.0, 0.0, 1.0])
        assert float(selection.uploaded_parameter_count(mask, 100)) == 200
