"""Recurrent block math: chunked mLSTM == sequential mLSTM; RG-LRU
associative scan == sequential recurrence; state continuity across splits
(the property that makes constant-memory decode correct)."""
import dataclasses

import hypothesis as hp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.models import recurrent


def _mlstm_inputs(key, B, S, H, hd):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd)) / np.sqrt(hd)
    v = jax.random.normal(ks[2], (B, S, H, hd))
    log_i = jax.random.normal(ks[3], (B, S, H))
    log_f = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, S, H)) + 2.0)
    C0 = jnp.zeros((B, H, hd, hd))
    n0 = jnp.zeros((B, H, hd))
    m0 = jnp.zeros((B, H))
    return q, k, v, log_i, log_f, C0, n0, m0


@hp.given(st.integers(1, 3), st.sampled_from([4, 17, 64, 100]),
          st.integers(1, 2), st.sampled_from([8, 16]),
          st.integers(0, 2**31 - 1))
@hp.settings(max_examples=20, deadline=None)
def test_mlstm_chunked_equals_sequential(B, S, H, hd, seed):
    args = _mlstm_inputs(jax.random.PRNGKey(seed), B, S, H, hd)
    h_seq, C_s, n_s, m_s = recurrent.mlstm_sequential(*args)
    h_chk, C_c, n_c, m_c = recurrent.mlstm_chunked(*args, chunk=16)
    np.testing.assert_allclose(h_chk, h_seq, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(C_c, C_s, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(m_c, m_s, rtol=1e-5, atol=1e-5)


def test_mlstm_carry_continuity():
    """Processing [0:S1] then [S1:S] with carried state == one pass."""
    B, S, H, hd = 2, 48, 2, 8
    q, k, v, li, lf, C0, n0, m0 = _mlstm_inputs(jax.random.PRNGKey(3),
                                                B, S, H, hd)
    full, Cf, nf, mf = recurrent.mlstm_chunked(q, k, v, li, lf, C0, n0, m0,
                                               chunk=16)
    S1 = 20
    h1, C1, n1, m1 = recurrent.mlstm_chunked(
        q[:, :S1], k[:, :S1], v[:, :S1], li[:, :S1], lf[:, :S1],
        C0, n0, m0, chunk=16)
    h2, C2, n2, m2 = recurrent.mlstm_chunked(
        q[:, S1:], k[:, S1:], v[:, S1:], li[:, S1:], lf[:, S1:],
        C1, n1, m1, chunk=16)
    np.testing.assert_allclose(jnp.concatenate([h1, h2], 1), full,
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(C2, Cf, rtol=2e-4, atol=2e-4)


def test_rglru_decode_continuity():
    """Full-sequence RG-LRU == prefill + per-token decode."""
    cfg = dataclasses.replace(get_arch("recurrentgemma-9b").reduced(),
                              dtype="float32")
    params = recurrent.rglru_init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 24
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    full, _ = recurrent.rglru_apply(params, x, cfg, mode="train")
    cache = recurrent.init_rglru_cache(cfg, B, jnp.float32)
    outs = []
    for t in range(S):
        o, cache = recurrent.rglru_apply(params, x[:, t:t + 1], cfg,
                                         mode="decode", layer_cache=cache)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(step, full, rtol=2e-4, atol=2e-4)


def test_slstm_decode_continuity():
    cfg = dataclasses.replace(get_arch("xlstm-350m").reduced(),
                              dtype="float32")
    params = recurrent.slstm_init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    full, _ = recurrent.slstm_apply(params, x, cfg, mode="train")
    cache = recurrent.init_slstm_cache(cfg, B)
    outs = []
    for t in range(S):
        o, cache = recurrent.slstm_apply(params, x[:, t:t + 1], cfg,
                                         mode="decode", layer_cache=cache)
        outs.append(o)
    np.testing.assert_allclose(jnp.concatenate(outs, 1), full,
                               rtol=2e-4, atol=2e-4)


def test_rglru_forgets_distant_past():
    """Sub-quadratic sanity: with strong decay the state forgets, so the
    constant-size cache is a faithful summary (long_500k feasibility)."""
    cfg = dataclasses.replace(get_arch("recurrentgemma-9b").reduced(),
                              dtype="float32")
    params = recurrent.rglru_init(jax.random.PRNGKey(0), cfg)
    B, S = 1, 64
    x1 = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    x2 = x1.at[:, :8].set(jax.random.normal(jax.random.PRNGKey(2),
                                            (B, 8, cfg.d_model)))
    o1, _ = recurrent.rglru_apply(params, x1, cfg, mode="train")
    o2, _ = recurrent.rglru_apply(params, x2, cfg, mode="train")
    # early perturbation decays: last-token outputs much closer than early
    d_early = float(jnp.abs(o1[:, 7] - o2[:, 7]).mean())
    d_late = float(jnp.abs(o1[:, -1] - o2[:, -1]).mean())
    assert d_late < d_early
