"""core/rounds stage engine: refactor equivalence contract (golden
values captured from the pre-refactor implementations), robust-
aggregation properties under Byzantine workers, compressed downlink
with PS-side error feedback, adaptive per-worker wire tiers, unified
telemetry on every path, and dtype-aware byte accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import budget, channel
from repro.comm.budget import CommConfig
from repro.core import mdsl, rounds, swarm_dist
from repro.core.mdsl import MdslConfig
from repro.core.pso import PsoHyperParams
from repro.core.swarm_dist import DistSwarmConfig

KEY = jax.random.PRNGKey(0)

# ---------------------------------------------------------------------------
# golden values: outputs of the PRE-refactor `mdsl_round` /
# `build_train_step` / `fedavg_train_step` on the scenarios below
# (default CommConfig, identical keys), captured at commit a80fffe.
# The pipeline rewrite must reproduce them.
# ---------------------------------------------------------------------------

GOLDEN_A_GLOBAL_W = [1.80128232e-01, 2.31610879e-01, -2.86240667e-01,
                     2.56585568e-01, -3.08933437e-01, 2.93604940e-01,
                     -2.87833601e-01, 1.86282575e-01, 1.72904655e-01,
                     -2.41597712e-01, -2.41481274e-01, 3.03303987e-01,
                     1.22825637e-01, 2.72431582e-01, -2.92581409e-01]
GOLDEN_A_GLOBAL_B = [-2.67224669e-01, 7.83292204e-02, 2.51419336e-01]
GOLDEN_A_LOSSES = [7.22671449e-01, 7.31087863e-01, 7.29802847e-01,
                   7.94080496e-01]
GOLDEN_A_THETA = [6.50404274e-01, 6.82979047e-01, 7.06822574e-01,
                  7.89672434e-01]
GOLDEN_A_MASK = [1.0, 1.0, 1.0, 0.0]
GOLDEN_A_GLOBAL_LOSS = 7.27651119e-01
GOLDEN_A_BYTES_UP = 216.0
GOLDEN_A_BYTES_DOWN = 288.0

GOLDEN_B_GLOBAL_W = [-2.84974761e-02, 3.83706987e-01, -2.87333608e-01,
                     -2.04035312e-01, -1.62206486e-01, 4.89676893e-01,
                     -5.31331562e-02, -7.95307755e-02, 1.17682204e-01,
                     -2.71218508e-01, 3.40326071e-01, -4.78067808e-02,
                     -9.34248269e-02, -2.00849637e-01, 1.59204692e-01,
                     -2.55024940e-01, 1.22836195e-02, 9.44640934e-02]
GOLDEN_B_GLOBAL_B = [-2.19994038e-01, 9.50741814e-04, 2.19043285e-01]
GOLDEN_B_LOSSES = [6.29979491e-01, 8.05368781e-01, 7.59640336e-01]
GOLDEN_B_THETA = [5.66981554e-01, 7.24831879e-01, 6.83676302e-01]
GOLDEN_B_GLOBAL_LOSS = 7.11177707e-01
GOLDEN_B_BYTES_UP = 252.0

# golden values for the non-default channel configs, captured at commit
# 750e995 (the last pre-phy commit): the phy refactor must keep the
# legacy erasure / awgn / adaptive-tier paths bit-identical.
GOLDEN_ERA_GLOBAL_W = [0.135449916, 0.226245284, -0.289681226, 0.250264257,
                       -0.308933437, 0.295656949, -0.259533823, 0.191622138,
                       0.0407505482, -0.176750511, -0.292056501, 0.303303987,
                       0.0843151435, 0.26603356, -0.297985822]
GOLDEN_ERA_GLOBAL_LOSS = 0.73827064037323
GOLDEN_ERA_DELIVERED = 2.0
GOLDEN_AWGN_GLOBAL_W = [0.301156342, 0.105007783, -0.281119287, 0.254952878,
                        -0.34334144, 0.212368816, -0.232611135, 0.255858243,
                        0.315140545, -0.296375543, -0.0496297143, 0.314203143,
                        0.0544373989, 0.256068319, -0.354990304]
GOLDEN_AWGN_GLOBAL_LOSS = 0.7636064291000366
GOLDEN_ADA_GLOBAL_W = [0.173703074, 0.228680268, -0.288142622, 0.260713965,
                       -0.310938179, 0.293189913, -0.285233527, 0.179208964,
                       0.174069017, -0.246297121, -0.240510464, 0.301520228,
                       0.122098073, 0.270038337, -0.28972277]
GOLDEN_ADA_GLOBAL_LOSS = 0.7268823385238647
GOLDEN_ADA_BYTES_UP = 70.0
GOLDEN_MESH_ERA_GLOBAL_W = [-0.0406506918, 0.353791028, -0.245264471,
                            -0.222518235, -0.111626387, 0.457579792,
                            0.0347295441, -0.17836386, 0.128652573,
                            -0.281817734, 0.425222874, -0.122104369,
                            -0.219926447, -0.169782877, 0.254639536,
                            -0.360587358, -0.0199347381, 0.232244834]
GOLDEN_MESH_ERA_GLOBAL_LOSS = 0.8411996364593506

GOLDEN_F_GLOBAL_W = [-1.40705062e-02, 2.38054156e-01, -1.56107754e-01,
                     -1.07632339e-01, -4.92234156e-02, 2.80290931e-01,
                     -4.26485874e-02, -4.44932096e-02, 7.21600577e-02,
                     -1.83111951e-01, 2.49882087e-01, -4.54693474e-02,
                     -4.35862467e-02, -1.58165574e-01, 6.66820556e-02,
                     -1.74432680e-01, -9.04508308e-03, 3.52005400e-02]
GOLDEN_F_GLOBAL_B = [-1.44934461e-01, -4.75801248e-03, 1.49692491e-01]
GOLDEN_F_GLOBAL_LOSS = 8.34809184e-01


def _paper_scenario(algorithm="mdsl", comm=CommConfig(), rounds_n=3):
    C, din, L = 4, 5, 3
    key = jax.random.PRNGKey(42)
    w_true = jax.random.normal(key, (din, L))
    xs = jax.random.normal(jax.random.fold_in(key, 1), (C, 32, din))
    ys = jnp.argmax(jnp.einsum("cnd,dl->cnl", xs, w_true), axis=-1)
    gx = jax.random.normal(jax.random.fold_in(key, 2), (48, din))
    gy = jnp.argmax(gx @ w_true, axis=-1)

    def init(k):
        return {"w": 0.01 * jax.random.normal(k, (din, L)),
                "b": jnp.zeros((L,))}

    def loss_fn(p, x, y):
        logits = x @ p["w"] + p["b"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logp, y[..., None], -1).mean()

    cfg = MdslConfig(algorithm=algorithm, local_epochs=2, batch_size=16,
                     hp=PsoHyperParams(learning_rate=0.2,
                                       velocity_clip=0.1), comm=comm)
    state = mdsl.init_state(jax.random.fold_in(key, 3), init, C,
                            eta=jnp.arange(C, dtype=jnp.float32) / C)
    n_params = mdsl.count_params(state.global_params)
    for r in range(rounds_n):
        state, m = mdsl.mdsl_round(
            state, xs, ys, gx, gy, jax.random.fold_in(key, 100 + r),
            loss_fn=loss_fn, eval_fn=loss_fn, cfg=cfg, n_params=n_params)
    return state, m


def _mesh_scenario(fedavg=False, comm=CommConfig(), steps=3):
    W, din, dout = 3, 6, 3
    key = jax.random.PRNGKey(7)
    w_true = jax.random.normal(key, (din, dout))
    xs = jax.random.normal(jax.random.fold_in(key, 1), (W, 16, din))
    ys = jnp.argmax(xs @ w_true, axis=-1)
    batch = {"x": xs, "y": ys}
    eval_batch = {"x": xs[0], "y": ys[0]}

    def loss_fn(p, b):
        logits = b["x"] @ p["w"] + p["b"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logp, b["y"][..., None], -1).mean()

    params = {"w": 0.05 * jax.random.normal(jax.random.fold_in(key, 2),
                                            (din, dout)),
              "b": jnp.zeros((dout,))}
    cfg = DistSwarmConfig(worker_axes=(), num_spatial=W, local_steps=2,
                          hp=PsoHyperParams(learning_rate=0.2,
                                            velocity_clip=0.5), comm=comm)
    build = (swarm_dist.fedavg_train_step if fedavg
             else swarm_dist.build_train_step)
    step = jax.jit(build(loss_fn, cfg))
    state = swarm_dist.init_state(params, cfg)
    for r in range(steps):
        state, info = step(state, batch, eval_batch,
                           jax.random.PRNGKey(60 + r))
    return state, info


class TestRefactorEquivalence:
    """With default CommConfig and identical keys, the pipeline must
    reproduce the pre-refactor implementations to float tolerance."""

    def test_paper_round_matches_golden(self):
        state, m = _paper_scenario()
        np.testing.assert_allclose(np.asarray(state.global_params["w"]),
                                   np.asarray(GOLDEN_A_GLOBAL_W,
                                              np.float32).reshape(5, 3),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(state.global_params["b"]),
                                   GOLDEN_A_GLOBAL_B, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(m.losses), GOLDEN_A_LOSSES,
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(m.theta), GOLDEN_A_THETA,
                                   rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(m.mask), GOLDEN_A_MASK)
        assert float(m.global_loss) == pytest.approx(GOLDEN_A_GLOBAL_LOSS,
                                                     rel=1e-5)
        assert float(m.bytes_up) == GOLDEN_A_BYTES_UP
        assert float(m.bytes_down) == GOLDEN_A_BYTES_DOWN

    def test_mesh_step_matches_golden(self):
        state, info = _mesh_scenario()
        np.testing.assert_allclose(np.asarray(state.global_params["w"]),
                                   np.asarray(GOLDEN_B_GLOBAL_W,
                                              np.float32).reshape(6, 3),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(state.global_params["b"]),
                                   GOLDEN_B_GLOBAL_B, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(info.losses), GOLDEN_B_LOSSES,
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(info.theta), GOLDEN_B_THETA,
                                   rtol=1e-5)
        assert float(info.global_loss) == pytest.approx(
            GOLDEN_B_GLOBAL_LOSS, rel=1e-5)
        assert float(info.bytes_up) == GOLDEN_B_BYTES_UP

    def test_erasure_paper_round_matches_golden(self):
        """Packet-erasure path through the new phy seam: bit-identical
        to the pre-phy `erasure_mask` implementation (same ekey
        bernoulli, survivor-normalized mean)."""
        state, m = _paper_scenario(
            comm=CommConfig(channel="erasure", drop_prob=0.4))
        np.testing.assert_allclose(np.asarray(state.global_params["w"]),
                                   np.asarray(GOLDEN_ERA_GLOBAL_W,
                                              np.float32).reshape(5, 3),
                                   rtol=1e-6, atol=1e-7)
        assert float(m.global_loss) == pytest.approx(
            GOLDEN_ERA_GLOBAL_LOSS, rel=1e-6)
        assert float(m.delivered) == GOLDEN_ERA_DELIVERED

    def test_awgn_paper_round_matches_golden(self):
        """Analog-aggregation AWGN through the new phy seam: the
        superposed-signal noise path (shared SNR) is unchanged."""
        state, m = _paper_scenario(
            comm=CommConfig(channel="awgn", snr_db=10.0))
        np.testing.assert_allclose(np.asarray(state.global_params["w"]),
                                   np.asarray(GOLDEN_AWGN_GLOBAL_W,
                                              np.float32).reshape(5, 3),
                                   rtol=1e-6, atol=1e-7)
        assert float(m.global_loss) == pytest.approx(
            GOLDEN_AWGN_GLOBAL_LOSS, rel=1e-6)

    def test_adaptive_two_tier_matches_golden(self):
        """The widened N-tier machinery keeps the legacy two-tier
        score-ranked default bit-identical (same split boundary, same
        wire selection, same byte charge)."""
        state, m = _paper_scenario(
            comm=CommConfig(compressor="int8", adaptive_bits=True))
        np.testing.assert_allclose(np.asarray(state.global_params["w"]),
                                   np.asarray(GOLDEN_ADA_GLOBAL_W,
                                              np.float32).reshape(5, 3),
                                   rtol=1e-6, atol=1e-7)
        assert float(m.global_loss) == pytest.approx(
            GOLDEN_ADA_GLOBAL_LOSS, rel=1e-6)
        assert float(m.bytes_up) == GOLDEN_ADA_BYTES_UP

    def test_erasure_mesh_step_matches_golden(self):
        state, info = _mesh_scenario(
            comm=CommConfig(channel="erasure", drop_prob=0.4))
        np.testing.assert_allclose(np.asarray(state.global_params["w"]),
                                   np.asarray(GOLDEN_MESH_ERA_GLOBAL_W,
                                              np.float32).reshape(6, 3),
                                   rtol=1e-6, atol=1e-7)
        assert float(info.global_loss) == pytest.approx(
            GOLDEN_MESH_ERA_GLOBAL_LOSS, rel=1e-6)

    def test_fedavg_mesh_step_matches_golden(self):
        state, info = _mesh_scenario(fedavg=True)
        np.testing.assert_allclose(np.asarray(state.global_params["w"]),
                                   np.asarray(GOLDEN_F_GLOBAL_W,
                                              np.float32).reshape(6, 3),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(state.global_params["b"]),
                                   GOLDEN_F_GLOBAL_B, rtol=1e-5, atol=1e-6)
        assert float(info.global_loss) == pytest.approx(
            GOLDEN_F_GLOBAL_LOSS, rel=1e-5)


class TestUnifiedTelemetry:
    """Satellite: the mesh path must no longer drop bytes_down /
    compression_ratio, and fedavg must report real per-worker losses."""

    def test_mesh_info_carries_wire_accounting(self):
        _, info = _mesh_scenario(comm=CommConfig(compressor="topk",
                                                 topk_ratio=0.25))
        n = 6 * 3 + 3
        assert float(info.bytes_down) == pytest.approx(3 * n * 4)
        assert float(info.compression_ratio) > 1.0
        assert float(info.bytes_up) < float(info.mask.sum()) * n * 4
        # pre-refactor aliases resolve to the unified fields
        assert info.delivered_count is info.delivered
        assert info.eval_losses is info.losses

    def test_fedavg_reports_real_losses_and_theta(self):
        _, info = _mesh_scenario(fedavg=True)
        assert np.all(np.asarray(info.losses) > 0.0)
        np.testing.assert_array_equal(np.asarray(info.theta),
                                      np.asarray(info.losses))
        np.testing.assert_array_equal(np.asarray(info.mask), 1.0)

    def test_paper_and_mesh_schemas_are_identical(self):
        assert mdsl.RoundMetrics is swarm_dist.RoundInfo
        assert swarm_dist.RoundInfo is rounds.RoundTelemetry


class TestRobustAggregation:
    """Property: under byzantine=k amplified sign-flip deltas with an
    all-ones mask (the FedAvg exposure), masked-mean diverges with the
    attack magnitude while median / trimmed mean stay bounded by the
    honest deltas."""

    def _aggregate(self, aggregator, d, trim_ratio=0.3):
        cfg = CommConfig(aggregator=aggregator, trim_ratio=trim_ratio)
        g = {"x": jnp.zeros(d.shape[1:])}
        out, _ = channel.receive(cfg, g, {"x": d}, jnp.ones(d.shape[0]),
                                 KEY)
        return np.asarray(out["x"])

    @pytest.mark.parametrize("scale", [10.0, 1e3, 1e6])
    @pytest.mark.parametrize("k", [1, 3])
    def test_median_and_trimmed_bounded_where_mean_diverges(self, scale, k):
        C, n = 10, 32
        honest = 0.1 * jax.random.normal(KEY, (C, n))
        attacked = honest.at[-k:].set(-scale)
        honest_bound = float(jnp.abs(honest[:-k]).max())
        mean = self._aggregate("mean", attacked)
        med = self._aggregate("median", attacked)
        trim = self._aggregate("trimmed_mean", attacked)
        # the mean is dragged proportionally to the attack amplitude
        assert np.abs(mean).max() > scale * k / C * 0.9
        # robust aggregates never leave the honest range
        assert np.abs(med).max() <= honest_bound + 1e-6
        assert np.abs(trim).max() <= honest_bound + 1e-6

    def test_median_matches_numpy_on_delivered_subset(self):
        C, n = 7, 16
        d = jax.random.normal(KEY, (C, n))
        mask = jnp.asarray([1.0, 0.0, 1.0, 1.0, 0.0, 1.0, 1.0])
        cfg = CommConfig(aggregator="median")
        g = {"x": jnp.zeros(n)}
        out, _ = channel.receive(cfg, g, {"x": d}, mask, KEY)
        want = np.median(np.asarray(d)[np.asarray(mask) > 0], axis=0)
        np.testing.assert_allclose(np.asarray(out["x"]), want, rtol=1e-5,
                                   atol=1e-6)

    def test_trimmed_mean_matches_scipy_style_reference(self):
        C, n = 9, 8
        d = jax.random.normal(KEY, (C, n))
        cfg = CommConfig(aggregator="trimmed_mean", trim_ratio=0.25)
        g = {"x": jnp.zeros(n)}
        out, _ = channel.receive(cfg, g, {"x": d}, jnp.ones(C), KEY)
        s = np.sort(np.asarray(d), axis=0)
        t = int(0.25 * C)
        want = s[t:C - t].mean(axis=0)
        np.testing.assert_allclose(np.asarray(out["x"]), want, rtol=1e-5,
                                   atol=1e-6)

    def test_all_lost_round_leaves_global_unchanged(self):
        cfg = CommConfig(aggregator="median")
        g = {"x": jnp.full(5, 3.0)}
        out, _ = channel.receive(cfg, g, {"x": jnp.ones((4, 5))},
                                 jnp.zeros(4), KEY)
        np.testing.assert_array_equal(np.asarray(out["x"]), 3.0)

    def test_engine_median_survives_byzantine_fedavg(self):
        """End-to-end: fedavg (all workers aggregated) with gaussian
        byzantine noise learns under median, degrades under mean."""
        def run(aggregator):
            comm = CommConfig(byzantine=1, byzantine_mode="gaussian",
                              byzantine_scale=25.0, aggregator=aggregator)
            state, _ = _paper_scenario(algorithm="fedavg", comm=comm,
                                       rounds_n=4)
            C, din, L = 4, 5, 3
            key = jax.random.PRNGKey(42)
            w_true = jax.random.normal(key, (din, L))
            gx = jax.random.normal(jax.random.fold_in(key, 2), (48, din))
            gy = jnp.argmax(gx @ w_true, axis=-1)
            pred = jnp.argmax(gx @ state.global_params["w"]
                              + state.global_params["b"], axis=-1)
            return float((pred == gy).mean())

        assert run("median") > run("mean") + 0.1


class TestDownlinkCompression:
    def test_ps_error_feedback_telescopes(self):
        """The compressed broadcast trajectory tracks the exact
        aggregate to within one residual (Seide-style telescoping at
        the PS)."""
        cfg = CommConfig(downlink_compressor="int4")
        g = {"x": jnp.zeros(64)}
        exact = {"x": jnp.zeros(64)}
        res = rounds.init_ps_residual(g)
        key = KEY
        for s in range(40):
            key, k1, k2 = jax.random.split(key, 3)
            step = 0.1 * jax.random.normal(k1, (64,))
            exact = {"x": exact["x"] + step}
            g, res = rounds.downlink(cfg, {"x": g["x"] + step}, g, res, k2)
        np.testing.assert_allclose(np.asarray(g["x"] + res["x"]),
                                   np.asarray(exact["x"]), rtol=1e-4,
                                   atol=1e-4)

    def test_identity_downlink_is_noop(self):
        cfg = CommConfig()
        g = {"x": jnp.ones(8)}
        agg = {"x": jnp.full(8, 2.0)}
        res = rounds.init_ps_residual(g)
        out, new_res = rounds.downlink(cfg, agg, g, res, KEY)
        assert out is agg and new_res is res

    def test_bytes_down_reflects_downlink_compressor(self):
        tree = {"x": jnp.zeros(1000)}
        mask = jnp.ones(4)
        dense = budget.round_record(CommConfig(), tree, 4, mask, mask)
        comp = budget.round_record(CommConfig(downlink_compressor="int8"),
                                   tree, 4, mask, mask)
        assert float(dense.bytes_down) == 4 * 4000
        assert float(comp.bytes_down) == 4 * (1000 + 4)

    def test_engine_compressed_downlink_still_learns(self):
        comm = CommConfig(downlink_compressor="int8")
        state, m = _paper_scenario(comm=comm)
        base, m0 = _paper_scenario()
        assert float(m.bytes_down) < float(m0.bytes_down)
        # compressed broadcast stays in the same league
        assert float(m.global_loss) < float(m0.global_loss) + 0.2


class TestAdaptiveBits:
    def test_tiers_assigned_by_score_rank(self):
        cfg = CommConfig(compressor="int8", adaptive_bits=True)
        theta = jnp.asarray([3.0, 0.5, 2.0, 1.0])  # best: 1, 3, 2, 0
        tiers, lo = rounds.tier_masks(cfg, theta)
        assert [t.compressor for t in tiers] == ["int8", "int4"]
        np.testing.assert_array_equal(np.asarray(lo), [1.0, 0.0, 1.0, 0.0])

    def test_int4_has_no_lower_tier(self):
        cfg = CommConfig(compressor="int4", adaptive_bits=True)
        tiers, lo = rounds.tier_masks(cfg, jnp.zeros(4))
        assert len(tiers) == 1 and lo is None

    def test_adaptive_bytes_below_uniform(self):
        tree = {"x": jnp.zeros(1000)}
        mask = jnp.ones(8)
        lo = jnp.asarray([0.0] * 4 + [1.0] * 4)
        uni = budget.round_record(CommConfig(compressor="int8"), tree, 8,
                                  mask, mask)
        ada = budget.round_record(
            CommConfig(compressor="int8", adaptive_bits=True), tree, 8,
            mask, mask, tier_idx=lo.astype(jnp.int32))
        assert float(ada.bytes_up) < float(uni.bytes_up)
        assert float(ada.compression_ratio) > float(uni.compression_ratio)

    def test_engine_adaptive_run_learns_and_charges_less(self):
        comm = CommConfig(compressor="int8", adaptive_bits=True)
        state, m = _paper_scenario(comm=comm)
        _, m_uni = _paper_scenario(comm=CommConfig(compressor="int8"))
        assert float(m.bytes_up) <= float(m_uni.bytes_up)
        for leaf in jax.tree.leaves(state.global_params):
            assert bool(jnp.isfinite(leaf).all())


class TestByteAccounting:
    def test_dense_bytes_uses_dtype_itemsize(self):
        tree = {"w": jnp.zeros((10, 4), jnp.bfloat16),
                "b": jnp.zeros((4,), jnp.float32)}
        assert budget.dense_bytes(tree) == 10 * 4 * 2 + 4 * 4
        # identity payload matches the dtype-aware dense charge
        assert budget.payload_bytes(CommConfig(), tree) == \
            budget.dense_bytes(tree)

    def test_topk_payload_ships_native_dtype_values(self):
        tree = {"w": jnp.zeros((100,), jnp.bfloat16)}
        cfg = CommConfig(compressor="topk", topk_ratio=0.1)
        assert budget.payload_bytes(cfg, tree) == 10 * (2 + 4)

    def test_validate_rejects_new_bad_fields(self):
        with pytest.raises(ValueError):
            CommConfig(aggregator="mode").validate()
        with pytest.raises(ValueError):
            CommConfig(downlink_compressor="zip").validate()
        with pytest.raises(ValueError):
            CommConfig(trim_ratio=0.5).validate()

    def test_cli_validates_at_parse_time(self, capsys):
        import sys
        from unittest import mock

        from repro.launch import train
        argv = ["train", "--mode", "paper", "--topk-ratio", "7.0",
                "--compressor", "topk"]
        with mock.patch.object(sys, "argv", argv):
            with pytest.raises(SystemExit):
                train.main()
        assert "topk_ratio" in capsys.readouterr().err
