"""Property tests (hypothesis) for the selection/aggregation invariants
of §III-C — the system-level contracts the mesh step relies on."""
import hypothesis as hp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import selection
from repro.core.selection import SelectionState

finite = st.floats(min_value=0.0, max_value=10.0, allow_nan=False)


class TestSelectionRule:
    @hp.given(st.lists(finite, min_size=2, max_size=20))
    @hp.settings(max_examples=40, deadline=None)
    def test_at_least_one_selected(self, thetas):
        theta = jnp.asarray(thetas, jnp.float32)
        mask, _ = selection.select_workers(
            theta, SelectionState(jnp.asarray(-1.0)))  # impossible bar
        assert float(mask.sum()) >= 1.0

    @hp.given(st.lists(finite, min_size=2, max_size=20), finite)
    @hp.settings(max_examples=40, deadline=None)
    def test_threshold_semantics(self, thetas, bar):
        theta = jnp.asarray(thetas, jnp.float32)
        mask, nxt = selection.select_workers(
            theta, SelectionState(jnp.asarray(bar, jnp.float32)))
        below = np.asarray(theta) <= bar
        if below.any():  # Eq. 6 exactly when non-degenerate
            np.testing.assert_array_equal(np.asarray(mask) > 0, below)
        # next threshold is this round's mean (Eq. 6's bar update)
        assert abs(float(nxt.prev_theta_mean) - float(theta.mean())) < 1e-5

    @hp.given(st.lists(finite, min_size=2, max_size=20))
    @hp.settings(max_examples=20, deadline=None)
    def test_round0_selects_all(self, thetas):
        theta = jnp.asarray(thetas, jnp.float32)
        mask, _ = selection.select_workers(
            theta, selection.init_selection_state())
        assert float(mask.sum()) == len(thetas)


class TestAggregation:
    def _tree(self, key, C, dim=5):
        k1, k2, k3 = jax.random.split(key, 3)
        g = {"w": jax.random.normal(k1, (dim,))}
        new = {"w": jax.random.normal(k2, (C, dim))}
        prev = {"w": jax.random.normal(k3, (C, dim))}
        return g, new, prev

    @hp.given(st.integers(2, 12), st.integers(0, 2 ** 12 - 1))
    @hp.settings(max_examples=30, deadline=None)
    def test_all_selected_equals_mean_delta(self, C, seed):
        g, new, prev = self._tree(jax.random.PRNGKey(seed), C)
        mask = jnp.ones((C,))
        out = selection.aggregate_global(g, new, prev, mask)
        expect = g["w"] + (new["w"] - prev["w"]).mean(axis=0)
        np.testing.assert_allclose(out["w"], expect, rtol=2e-5, atol=2e-6)

    @hp.given(st.integers(2, 12), st.integers(0, 11), st.integers(0, 99))
    @hp.settings(max_examples=30, deadline=None)
    def test_single_selected_is_that_delta(self, C, pick, seed):
        pick = pick % C
        g, new, prev = self._tree(jax.random.PRNGKey(seed), C)
        mask = jnp.zeros((C,)).at[pick].set(1.0)
        out = selection.aggregate_global(g, new, prev, mask)
        expect = g["w"] + (new["w"][pick] - prev["w"][pick])
        np.testing.assert_allclose(out["w"], expect, rtol=2e-5, atol=2e-6)

    @hp.given(st.integers(2, 10), st.integers(0, 99))
    @hp.settings(max_examples=20, deadline=None)
    def test_zero_delta_is_fixed_point(self, C, seed):
        g, new, _ = self._tree(jax.random.PRNGKey(seed), C)
        mask = jnp.ones((C,))
        out = selection.aggregate_global(g, new, new, mask)
        np.testing.assert_allclose(out["w"], g["w"], rtol=1e-6)

    @hp.given(st.integers(2, 10), st.integers(0, 99))
    @hp.settings(max_examples=20, deadline=None)
    def test_comm_accounting(self, C, seed):
        """§IV-C: uploads = n * sum(s_i) <= n * C (FedAvg)."""
        mask = (jax.random.uniform(jax.random.PRNGKey(seed), (C,))
                > 0.5).astype(jnp.float32)
        n = 1234
        up = selection.uploaded_parameter_count(mask, n)
        assert float(up) == float(mask.sum()) * n
        assert float(up) <= n * C
