"""Sharding rules + param-spec resolution, and a subprocess mini-mesh
lowering check (the full 512-device dry-run runs via launch/dryrun.py)."""
import subprocess
import sys
from pathlib import Path

import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding.rules import SINGLE_POD_FSDP_TP, SINGLE_POD_TP


class TestRules:
    def test_spec_resolution(self):
        spec = SINGLE_POD_TP.spec(("batch", "seq", "heads"))
        assert spec == P(None, None, "model")

    def test_spec_dedup(self):
        r = SINGLE_POD_FSDP_TP
        spec = r.spec(("expert", "embed_fsdp", "expert_mlp"))
        assert spec == P("data", None, "model")  # embed_fsdp dropped

    def test_unknown_logical_axis_replicates(self):
        assert SINGLE_POD_TP.spec(("nonexistent",)) == P(None)


class TestParamSpecs:
    def _mesh(self):
        import jax
        import numpy as np
        from jax.sharding import Mesh
        dev = np.array(jax.devices()[:1]).reshape(1, 1)
        return Mesh(dev, ("data", "model"))

    def test_divisibility_drop(self):
        """15 heads on a 16-way model axis -> replicated (no crash)."""
        from repro.sharding.param_specs import spec_for_path

        # faking a 16-wide model axis by reusing device 0 is not allowed;
        # directly exercise the divisibility logic with mesh.shape
        class FakeMesh:
            shape = {"data": 16, "model": 16}
        spec = spec_for_path("groups/b0/temporal/wq", (960, 15, 64),
                             SINGLE_POD_TP, FakeMesh())
        assert spec == P(None, None, None)  # heads 15 % 16 != 0
        spec = spec_for_path("groups/b0/mlp/wi", (960, 2560),
                             SINGLE_POD_TP, FakeMesh())
        assert spec == P(None, "model")     # 2560 % 16 == 0

    def test_moe_spec(self):
        class FakeMesh:
            shape = {"data": 16, "model": 16}
        from repro.sharding.param_specs import spec_for_path
        spec = spec_for_path("groups/b0/moe/wi", (2, 128, 2048, 768),
                             SINGLE_POD_FSDP_TP, FakeMesh())
        assert spec == P(None, "data", None, "model")

    def test_cache_spec(self):
        class FakeMesh:
            shape = {"data": 16, "model": 16}
        from repro.sharding.param_specs import spec_for_path
        # kv=16 divides the model axis -> head-sharded cache
        spec = spec_for_path("groups/b0/temporal/k", (16, 128, 32768, 16, 128),
                             SINGLE_POD_FSDP_TP, FakeMesh(), table="cache")
        assert spec == P(None, "data", None, "model", None)
        # kv=8 does NOT divide -> dropped (serve_rules then seq-shards
        # the cache over "model" instead, see launch/steps.py)
        spec = spec_for_path("groups/b0/temporal/k", (16, 128, 32768, 8, 128),
                             SINGLE_POD_FSDP_TP, FakeMesh(), table="cache")
        assert spec == P(None, "data", None, None, None)


MINI_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.configs.base import get_arch, InputShape
from repro.launch.steps import build_step
mesh = jax.make_mesh((2, 4), ("data", "model"))
ok = []
for arch in ["smollm-360m", "qwen3-moe-30b-a3b", "recurrentgemma-9b"]:
    for shape in [InputShape("t", 128, 8, "train"),
                  InputShape("d", 256, 8, "decode")]:
        built = build_step(get_arch(arch).reduced(), shape, mesh)
        built.fn.lower(*built.args).compile()
        ok.append(f"{arch}:{shape.kind}")
print("LOWERED", len(ok))
"""


@pytest.mark.slow
def test_mini_mesh_lowering():
    """Reduced configs lower+compile on an 8-device (2x4) host mesh.
    Runs in a subprocess because the device count must be set before jax
    initializes."""
    env = {"PYTHONPATH": str(Path(__file__).parent.parent / "src"),
           "PATH": "/usr/bin:/bin"}
    import os
    env.update({k: v for k, v in os.environ.items()
                if k not in ("XLA_FLAGS",)})
    env["PYTHONPATH"] = str(Path(__file__).parent.parent / "src")
    out = subprocess.run([sys.executable, "-c", MINI_MESH_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert "LOWERED 6" in out.stdout, out.stderr[-2000:]
