"""comm.straggler — deadline-driven straggler engine invariants: the
fresh/late partition of a selected cohort, gamma=0 drain telescoping,
bitwise quorum holds, deterministic fault schedules with exact byte
accounting, and the buffered-vs-dropped age semantics."""
import hypothesis as hp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import budget, compress, phy, straggler
from repro.comm.budget import CommConfig
from repro.core import mdsl
from repro.core.mdsl import MdslConfig
from repro.core.pso import PsoHyperParams

KEY = jax.random.PRNGKey(0)


def _tree(key, C, shapes=((4,), (3, 2))):
    ks = jax.random.split(key, len(shapes))
    return {f"p{i}": jax.random.normal(k, (C,) + s)
            for i, (k, s) in enumerate(zip(ks, shapes))}


def _global(tree):
    return jax.tree.map(lambda x: jnp.zeros(x.shape[1:], x.dtype), tree)


def _scfg(**kw):
    kw.setdefault("round_deadline_s", 1.0)
    return CommConfig(**kw)


class TestAdvanceAge:
    def test_buffered_differs_from_dropped(self):
        """A late-but-parked upload resets the worker's age to 1 (the PS
        heard from it, one round ago); a silent worker just ages."""
        st_ = phy.init_state(CommConfig(), 3)
        st_ = phy.advance_age(st_, jnp.asarray([1.0, 0.0, 0.0]),
                              buffered=jnp.asarray([0, 1, 0]))
        np.testing.assert_array_equal(np.asarray(st_.age), [0, 1, 1])
        st_ = phy.advance_age(st_, jnp.asarray([0.0, 0.0, 0.0]),
                              buffered=jnp.asarray([0, 1, 0]))
        np.testing.assert_array_equal(np.asarray(st_.age), [1, 1, 2])

    def test_legacy_pinned_without_buffered(self):
        """buffered=None is the exact pre-straggler semantics."""
        a = phy.init_state(CommConfig(), 3)
        b = phy.init_state(CommConfig(), 3)
        for mask in ([1.0, 0.0, 1.0], [0.0, 0.0, 1.0]):
            m = jnp.asarray(mask)
            a = phy.advance_age(a, m)
            b = phy.advance_age(b, m, buffered=None)
        np.testing.assert_array_equal(np.asarray(a.age), np.asarray(b.age))

    def test_delivery_beats_buffered(self):
        st_ = phy.init_state(CommConfig(), 2)
        st_ = phy.advance_age(st_, jnp.asarray([1.0, 1.0]),
                              buffered=jnp.asarray([1, 1]))
        np.testing.assert_array_equal(np.asarray(st_.age), [0, 0])


class TestLateMask:
    def test_extreme_deadlines(self):
        cfg = _scfg(round_deadline_s=1e9)
        tree = _tree(KEY, 5)
        mask = jnp.asarray([1.0, 0.0, 1.0, 1.0, 0.0])
        np.testing.assert_array_equal(
            np.asarray(straggler.late_mask(cfg, tree, mask)), 0.0)
        tight = _scfg(round_deadline_s=1e-12)
        np.testing.assert_array_equal(
            np.asarray(straggler.late_mask(tight, tree, mask)),
            np.asarray(mask))

    def test_snr_tail_goes_late(self):
        """Late is physics: the deadline sits between the fast and slow
        workers' airtimes, so exactly the low-SNR tail misses it."""
        cfg = _scfg()
        tree = _tree(KEY, 4)
        mask = jnp.ones((4,))
        wb = budget.worker_payload_bytes(cfg, tree, 4)
        snr = jnp.asarray([20.0, 20.0, -10.0, -10.0])
        air = np.asarray(budget.worker_airtime_s(cfg, wb, snr))
        mid = 0.5 * (air[0] + air[2])
        late = straggler.late_mask(cfg._replace(round_deadline_s=float(mid)),
                                   tree, mask, snr_db=snr)
        np.testing.assert_array_equal(np.asarray(late), [0.0, 0.0, 1.0, 1.0])

    def test_unselected_never_late(self):
        cfg = _scfg(round_deadline_s=1e-12)
        late = straggler.late_mask(cfg, _tree(KEY, 3), jnp.zeros((3,)))
        np.testing.assert_array_equal(np.asarray(late), 0.0)


class TestAggregateAndDrain:
    @hp.given(st.integers(2, 8), st.integers(0, 4))
    @hp.settings(max_examples=8, deadline=None)
    def test_fresh_and_late_partition_selected(self, C, seed):
        """On an ideal channel the selected cohort splits exactly into
        fresh (aggregated now) and late (parked): disjoint, covering."""
        k = jax.random.PRNGKey(seed)
        tree = _tree(k, C)
        g = _global(tree)
        buf = straggler.init_buffer(_scfg(), tree)
        mask = jax.random.bernoulli(jax.random.fold_in(k, 1), 0.7,
                                    (C,)).astype(jnp.float32)
        late = mask * jax.random.bernoulli(
            jax.random.fold_in(k, 2), 0.5, (C,)).astype(jnp.float32)
        _, fresh, newbuf, stats = straggler.aggregate_and_drain(
            _scfg(), g, tree, mask, late, jax.random.fold_in(k, 3),
            None, buf)
        fresh = np.asarray(fresh)
        late = np.asarray(late)
        np.testing.assert_array_equal(fresh * late, 0.0)
        np.testing.assert_array_equal(fresh + late, np.asarray(mask))
        # every late arrival parked at age 1
        np.testing.assert_array_equal(np.asarray(newbuf.age),
                                      late.astype(np.int32))
        assert float(stats.late) == late.sum()

    @hp.given(st.integers(2, 8), st.integers(0, 4))
    @hp.settings(max_examples=8, deadline=None)
    def test_gamma_zero_drain_telescopes(self, C, seed):
        """gamma=0: a delta buffered one round and then drained lands in
        the aggregate exactly as if it had arrived on time."""
        k = jax.random.PRNGKey(seed)
        tree = _tree(k, C)
        g = _global(tree)
        cfg = _scfg(staleness_gamma=0.0)
        zeros = jax.tree.map(jnp.zeros_like, tree)
        empty = straggler.init_buffer(cfg, tree)
        on_time, _, _, _ = straggler.aggregate_and_drain(
            cfg, g, tree, jnp.ones((C,)), jnp.zeros((C,)),
            jax.random.fold_in(k, 1), None, empty)
        parked = straggler.StragglerBuffer(
            delta=tree, age=jnp.ones((C,), jnp.int32))
        drained, _, newbuf, stats = straggler.aggregate_and_drain(
            cfg, g, zeros, jnp.zeros((C,)), jnp.zeros((C,)),
            jax.random.fold_in(k, 2), None, parked)
        for a, b in zip(jax.tree.leaves(on_time), jax.tree.leaves(drained)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-7)
        assert float(stats.drained) == C
        assert float(stats.buffered) == 0  # applied round clears the slots

    def test_quorum_hold_is_bitwise(self):
        C = 4
        tree = _tree(KEY, C)
        g = jax.tree.map(lambda x: jax.random.normal(KEY, x.shape[1:]), tree)
        cfg = _scfg(quorum=C + 5)
        buf = straggler.init_buffer(cfg, tree)
        out, _, newbuf, stats = straggler.aggregate_and_drain(
            cfg, g, tree, jnp.ones((C,)), jnp.zeros((C,)), KEY, None, buf)
        for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(out)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        assert float(stats.held) == 1.0
        assert float(stats.drained) == 0.0
        # fresh arrivals on a held round park instead of vanishing
        np.testing.assert_array_equal(np.asarray(newbuf.age), 1)

    def test_held_round_ages_survivors(self):
        C = 3
        tree = _tree(KEY, C)
        cfg = _scfg(quorum=C + 5)
        parked = straggler.StragglerBuffer(
            delta=tree, age=jnp.asarray([2, 1, 0], jnp.int32))
        _, _, newbuf, stats = straggler.aggregate_and_drain(
            cfg, _global(tree), jax.tree.map(jnp.zeros_like, tree),
            jnp.zeros((C,)), jnp.zeros((C,)), KEY, None, parked)
        np.testing.assert_array_equal(np.asarray(newbuf.age), [3, 2, 0])
        assert float(stats.held) == 1.0

    def test_staleness_weights_decay(self):
        cfg = _scfg(staleness_gamma=1.0)
        w = np.asarray(straggler.staleness_weights(
            cfg, jnp.asarray([0, 1, 2, 4], jnp.int32)))
        assert w[0] == 0.0  # empty slot
        np.testing.assert_allclose(w[1:], [0.5, 1 / 3, 0.2], rtol=1e-6)
        flat = np.asarray(straggler.staleness_weights(
            cfg._replace(staleness_gamma=0.0),
            jnp.asarray([0, 1, 7], jnp.int32)))
        np.testing.assert_array_equal(flat, [0.0, 1.0, 1.0])

    @pytest.mark.parametrize("agg", ["median", "trimmed_mean"])
    def test_robust_aggregators_compose(self, agg):
        C = 6
        tree = _tree(KEY, C)
        cfg = _scfg(aggregator=agg, trim_ratio=0.2)
        buf = straggler.StragglerBuffer(
            delta=tree, age=jnp.asarray([0, 0, 0, 1, 2, 0], jnp.int32))
        out, _, _, _ = straggler.aggregate_and_drain(
            cfg, _global(tree), tree, jnp.ones((C,)), jnp.zeros((C,)),
            KEY, None, buf)
        for leaf in jax.tree.leaves(out):
            assert bool(jnp.isfinite(leaf).all())


class TestFaultSchedule:
    def test_deterministic_and_replayable(self):
        cfg = CommConfig(fault_prob=0.5, fault_rounds=2, fault_seed=7)
        for t in range(6):
            a = np.asarray(straggler.alive_mask(cfg, jnp.int32(t), 16))
            b = np.asarray(straggler.alive_mask(cfg, jnp.int32(t), 16))
            np.testing.assert_array_equal(a, b)

    def test_outage_lasts_exactly_r_rounds(self):
        """down(t) == OR of the crash draws at t-r for r < R, so a crash
        at round t keeps the worker dark through t+R-1 and not beyond."""
        C, R = 32, 3
        cfg = CommConfig(fault_prob=0.3, fault_rounds=R, fault_seed=3)
        stream = jax.random.fold_in(jax.random.PRNGKey(cfg.fault_seed),
                                    straggler.FAULT_SALT)
        crash = {t: np.asarray(jax.random.bernoulli(
            jax.random.fold_in(stream, t), cfg.fault_prob, (C,)))
            for t in range(10)}
        for t in range(10):
            want = np.zeros((C,), bool)
            for r in range(R):
                if t - r >= 0:
                    want |= crash[t - r]
            got = np.asarray(straggler.alive_mask(cfg, jnp.int32(t), C))
            np.testing.assert_array_equal(got, (~want).astype(np.float32))

    def test_no_faults_all_alive(self):
        cfg = CommConfig()
        assert not straggler.fault_mode(cfg)
        np.testing.assert_array_equal(
            np.asarray(straggler.alive_mask(
                cfg._replace(fault_prob=0.0), jnp.int32(4), 8)), 1.0)


class TestConfigGates:
    def test_packed_wire_ineligible_under_deadline(self):
        cfg = CommConfig(compressor="int8")
        tree = _global(_tree(KEY, 2))
        assert compress.packed_wire_eligible(cfg, tree)
        assert not compress.packed_wire_eligible(
            cfg._replace(round_deadline_s=0.5), tree)

    def test_deadline_needs_rate_model(self):
        with pytest.raises(ValueError, match="rate model"):
            CommConfig(round_deadline_s=0.5, bandwidth_hz=None).validate()

    def test_quorum_needs_deadline(self):
        with pytest.raises(ValueError, match="round_deadline_s"):
            CommConfig(quorum=3).validate()

    def test_quorum_exceeding_cohort_rejected(self):
        from repro.experiments.spec import ExperimentSpec, override
        spec = override(ExperimentSpec(), "data.num_workers=8",
                        "comm.round_deadline_s=0.5", "comm.quorum=9")
        with pytest.raises(ValueError, match="quorum"):
            spec.validate()

    def test_fault_prob_bounds(self):
        with pytest.raises(ValueError):
            CommConfig(fault_prob=1.0).validate()
        with pytest.raises(ValueError):
            CommConfig(fault_prob=-0.1).validate()
        CommConfig(fault_prob=0.99, fault_rounds=3).validate()

    def test_buffer_none_when_inactive(self):
        assert straggler.init_buffer(CommConfig(), _tree(KEY, 4)) is None


class TestEngineIntegration:
    """The tiny logistic fleet from test_comm.py, through mdsl_round."""

    def _run(self, comm, rounds=4, C=6, seed=0, algorithm="mdsl"):
        din, L = 6, 3
        key = jax.random.PRNGKey(seed)
        w_true = jax.random.normal(key, (din, L))
        xs = jax.random.normal(jax.random.fold_in(key, 1), (C, 64, din))
        ys = jnp.argmax(jnp.einsum("cnd,dl->cnl", xs, w_true), axis=-1)
        gx = jax.random.normal(jax.random.fold_in(key, 2), (128, din))
        gy = jnp.argmax(gx @ w_true, axis=-1)

        def init(k):
            return {"w": 0.01 * jax.random.normal(k, (din, L)),
                    "b": jnp.zeros((L,))}

        def loss_fn(p, x, y):
            logits = x @ p["w"] + p["b"]
            logp = jax.nn.log_softmax(logits)
            return -jnp.take_along_axis(logp, y[..., None], -1).mean()

        cfg = MdslConfig(algorithm=algorithm, local_epochs=2, batch_size=32,
                         hp=PsoHyperParams(learning_rate=0.3,
                                           velocity_clip=0.1), comm=comm)
        state = mdsl.init_state(jax.random.fold_in(key, 3), init, C,
                                eta=jnp.zeros((C,)), comm=comm)
        n_params = mdsl.count_params(state.global_params)
        hist = []
        for r in range(rounds):
            state, m = mdsl.mdsl_round(
                state, xs, ys, gx, gy, jax.random.fold_in(key, 100 + r),
                loss_fn=loss_fn, eval_fn=loss_fn, cfg=cfg,
                n_params=n_params)
            hist.append(m)
        return state, hist, n_params

    def test_default_config_has_no_straggler_telemetry(self):
        _, hist, _ = self._run(CommConfig(), rounds=2)
        for m in hist:
            assert m.late is None and m.held is None
            assert m.transmitted is None

    def test_tight_deadline_parks_then_drains(self):
        comm = CommConfig(round_deadline_s=1e-12, quorum=2,
                          staleness_gamma=0.5)
        state, hist, _ = self._run(comm, rounds=3)
        # round 0: everyone late, nothing available -> quorum hold
        assert float(hist[0].late) > 0
        assert float(hist[0].held) == 1.0
        assert float(hist[0].buffered) > 0
        # a later round drains the parked deltas
        assert sum(float(m.drained) for m in hist[1:]) > 0
        for leaf in jax.tree.leaves(state.global_params):
            assert bool(jnp.isfinite(leaf).all())

    def test_churn_stays_finite_with_exact_byte_accounting(self):
        comm = CommConfig(round_deadline_s=1e9, fault_prob=0.4,
                          fault_rounds=2, fault_seed=5)
        state, hist, n = self._run(comm, rounds=5)
        for m in hist:
            # crashed workers transmit nothing: the wire bytes are the
            # transmitting-worker count times the dense payload, exactly
            assert float(m.bytes_up) == pytest.approx(
                float(m.transmitted) * n * 4)
            assert float(m.transmitted) <= float(m.selected_count)
        for leaf in jax.tree.leaves(state.global_params):
            assert bool(jnp.isfinite(leaf).all())

    def test_churn_recovers_buffer_returns_to_zero(self):
        comm = CommConfig(round_deadline_s=1e-12, fault_prob=0.3,
                          fault_rounds=1, fault_seed=2)
        _, hist, _ = self._run(comm, rounds=6)
        assert any(float(m.buffered) > 0 for m in hist)
        assert float(hist[-1].drained) > 0 or float(hist[-1].buffered) == 0
        # occupancy drains down within a round of parking
        occ = [float(m.buffered) for m in hist]
        assert min(occ[1:]) <= max(occ[:-1])
