"""Fused wire-path kernels (kernels/quant_pack EF pass + kernels/
wire_agg): bit-equality against the jnp oracles (also under vmap over
the stacked-worker axis), error-feedback telescoping through the fused
path, receive_packed == receive under erasure masks for every
aggregator, and the wire_round packed-route gate — including that every
golden-pinned engine config stays on the legacy route."""
import functools

import hypothesis as hp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import channel, compress
from repro.comm.budget import CommConfig
from repro.core import rounds
from repro.kernels import runtime
from repro.kernels.quant_pack import (dequant_unpack_2d, dequant_unpack_ref,
                                      dequantize_unpack, quant_pack_ef_2d,
                                      quant_pack_ef_ref, quantize_pack,
                                      quantize_pack_ef)
from repro.kernels.wire_agg import wire_agg_2d, wire_agg_ref, wire_aggregate

KEY = jax.random.PRNGKey(0)


def _xr(seed: int, shape=(256, 128)):
    k = jax.random.fold_in(KEY, seed)
    x = jax.random.normal(k, shape)
    r = 0.05 * jax.random.normal(jax.random.fold_in(k, 1), shape)
    return x, r


class TestFusedQuantPackEF:
    @hp.given(st.integers(0, 2**31 - 1), st.sampled_from([8, 4]))
    @hp.settings(max_examples=8, deadline=None)
    def test_kernel_matches_ref(self, seed, bits):
        x, r = _xr(seed % 1000)
        s = jnp.int32(seed)
        pk, sk, rk = quant_pack_ef_2d(x, r, s, bits=bits, interpret=True)
        pr, sr, rr = quant_pack_ef_ref(x, r, s, bits=bits)
        np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
        np.testing.assert_array_equal(np.asarray(sk), np.asarray(sr))
        np.testing.assert_array_equal(np.asarray(rk), np.asarray(rr))

    @hp.given(st.integers(1, 5), st.sampled_from([8, 4]),
              st.integers(0, 2**20))
    @hp.settings(max_examples=6, deadline=None)
    def test_vmap_over_worker_axis_bit_equal(self, C, bits, seed):
        # the engines' calling convention: vmap over stacked workers
        k = jax.random.fold_in(KEY, seed)
        xs = jax.random.normal(k, (C, 256, 128))
        rs = 0.1 * jax.random.normal(jax.random.fold_in(k, 1),
                                     (C, 256, 128))
        seeds = jnp.arange(C, dtype=jnp.int32) + seed % 97
        kern = jax.jit(jax.vmap(lambda x, r, s: quant_pack_ef_2d(
            x, r, s, bits=bits, interpret=True)))
        ref = jax.jit(jax.vmap(lambda x, r, s: quant_pack_ef_ref(
            x, r, s, bits=bits)))
        for a, b in zip(kern(xs, rs, seeds), ref(xs, rs, seeds)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("bits", [8, 4])
    def test_matches_legacy_compose(self, bits):
        """packed/scales/residual == quantize + decode + subtract, run
        in ONE jit (the engines' regime — XLA fuses the residual's
        multiply-subtract identically on both routes)."""
        x, r = _xr(3, (300, 7))
        s = jnp.int32(11)

        @jax.jit
        def legacy(x, r, s):
            p, sc = quantize_pack(x + r, s, bits=bits)
            wire = dequantize_unpack(p, sc, x.shape, bits=bits)
            return p, sc, (x + r) - wire

        fused = quantize_pack_ef(x, r, s, bits=bits)
        for a, b in zip(fused, legacy(x, r, s)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("bits", [8, 4])
    def test_dequant_kernel_matches_ref(self, bits):
        x, _ = _xr(4, (512, 128))
        p, s = quantize_pack(x, jnp.int32(5), bits=bits)
        dk = dequant_unpack_2d(p, s, bits=bits, interpret=True)
        dr = dequant_unpack_ref(p, s, bits=bits)
        np.testing.assert_array_equal(np.asarray(dk), np.asarray(dr))


class TestDispatch:
    def test_wire_ops_report_dispatch(self, monkeypatch):
        """Every wire-path wrapper notes its kernel/ref decision —
        including dequantize_unpack, which used to run the ref
        unconditionally without reporting."""
        seen = []
        monkeypatch.setattr(
            runtime, "note_dispatch",
            lambda name, interpret, **info: seen.append((name, interpret)))
        x, r = _xr(5, (300, 7))
        p, s, _ = quantize_pack_ef(x, r, jnp.int32(1), bits=8)
        dequantize_unpack(p, s, x.shape, bits=8)
        wire_aggregate(jnp.stack([p, p]), jnp.stack([s, s]), jnp.ones(2),
                       shape=x.shape, bits=8)
        names = {n for n, _ in seen}
        assert {"quant_pack_ef", "dequant_unpack", "wire_agg"} <= names, seen
        # CPU container: everything dispatches to the interpret/ref path
        assert all(interp for _, interp in seen), seen


class TestErrorFeedbackFused:
    @pytest.mark.parametrize("bits", [8, 4])
    def test_fused_step_tracks_legacy(self, bits):
        """Per round, on identical (delta, residual, key) inputs, the
        fused step emits the SAME payload bits and scales as
        compress_with_ef — so the decoded wire is bit-identical — while
        the new residual agrees up to XLA's FMA contraction of the
        final subtract (the legacy route subtracts at leaf shape after
        the dequant slice, the fused pass at the padded block shape;
        XLA is free to contract either).

        delta enters the jit as an INPUT, matching wire_round's regime
        (the engines' delta is a params subtract, not a raw multiply):
        if a caller's multiply fed the EF accumulate inside the same
        trace, XLA could FMA-contract it on one route only, shifting
        amax -> scale -> every decoded element by 1 ulp."""
        cfg = CommConfig(compressor=f"int{bits}")
        t = jnp.asarray([1.0, -2.0, 0.5, 3.0, -0.7, 0.1, 2.2, -1.4])

        @jax.jit
        def step_legacy(delta, res, key):
            wire, res = compress.compress_with_ef(cfg, {"x": delta}, res,
                                                  key)
            return wire["x"], res

        @jax.jit
        def step_packed(delta, res, key):
            pw, res = compress.compress_with_ef_packed(cfg, {"x": delta},
                                                       res, key)
            wire = dequantize_unpack(pw.packed[0], pw.scales[0], t.shape,
                                     bits=bits)
            return wire, res

        x, key = jnp.zeros(8), KEY
        res = compress.init_residual({"x": x})
        for _ in range(25):
            key, k = jax.random.split(key)
            delta = -0.2 * 2.0 * (x - t)
            wl, res_l = step_legacy(delta, res, k)
            wp, res_p = step_packed(delta, res, k)   # same inputs
            np.testing.assert_array_equal(np.asarray(wl), np.asarray(wp))
            np.testing.assert_allclose(np.asarray(res_p["x"]),
                                       np.asarray(res_l["x"]),
                                       rtol=0, atol=1e-6)
            res = res_l
            x = x + delta

    @pytest.mark.parametrize("bits", [8, 4])
    def test_telescoping_through_fused_path(self, bits):
        """EF telescoping (Seide et al.) survives the fused pass: the
        sum of decoded uploads tracks the sum of true deltas to within
        the final residual, exactly (within one jit the fused residual
        IS acc - wire, so the telescoping sum collapses)."""
        cfg = CommConfig(compressor=f"int{bits}")
        t = jnp.asarray([1.0, -2.0, 0.5, 3.0, -0.7, 0.1, 2.2, -1.4])

        @jax.jit
        def step(x, res, key):
            delta = -0.2 * 2.0 * (x - t)
            pw, res = compress.compress_with_ef_packed(
                cfg, {"x": delta}, res, key)
            wire = dequantize_unpack(pw.packed[0], pw.scales[0], t.shape,
                                     bits=bits)
            return wire, res, delta

        x, key = jnp.zeros(8), KEY
        res = compress.init_residual({"x": x})
        srv, sum_d = jnp.zeros(8), jnp.zeros(8)
        for _ in range(30):
            key, k = jax.random.split(key)
            wire, res, delta = step(x, res, k)
            srv, sum_d, x = srv + wire, sum_d + delta, x + delta
        np.testing.assert_allclose(np.asarray(srv + res["x"]),
                                   np.asarray(sum_d), rtol=0, atol=1e-5)
        # and the wire actually moved the server toward the delta sum
        assert np.abs(np.asarray(srv - sum_d)).max() < 0.05


class TestReceivePacked:
    @pytest.mark.parametrize("bits", [8, 4])
    @pytest.mark.parametrize("agg", ["mean", "median", "trimmed_mean"])
    def test_equals_legacy_receive_under_erasure(self, bits, agg):
        cfg = CommConfig(compressor=f"int{bits}", channel="erasure",
                         drop_prob=0.4, aggregator=agg)
        C = 6
        gp = {"w": jax.random.normal(KEY, (90, 11)),
              "b": jax.random.normal(jax.random.fold_in(KEY, 1), (13,))}
        delta = jax.tree.map(
            lambda x: 0.1 * jax.random.normal(jax.random.fold_in(KEY, 2),
                                              (C,) + x.shape), gp)
        residual = jax.tree.map(
            lambda x: jnp.zeros((C,) + x.shape, jnp.float32), gp)
        mask = jnp.array([1., 1., 0., 1., 1., 1.])
        qkey, wkey = jax.random.split(jax.random.fold_in(KEY, 3))

        @jax.jit
        def both(delta, residual, gp, qkey, wkey):
            keys = jax.random.split(qkey, C)
            wire, _ = jax.vmap(functools.partial(
                compress.compress_with_ef, cfg))(delta, residual, keys)
            agg_l, me_l = channel.receive(cfg, gp, wire, mask, wkey)
            pw, _ = jax.vmap(functools.partial(
                compress.compress_with_ef_packed, cfg))(delta, residual,
                                                        keys)
            agg_p, me_p = channel.receive_packed(cfg, gp, pw, mask, wkey)
            return agg_l, me_l, agg_p, me_p

        agg_l, me_l, agg_p, me_p = both(delta, residual, gp, qkey, wkey)
        np.testing.assert_array_equal(np.asarray(me_l), np.asarray(me_p))
        for k in gp:
            np.testing.assert_array_equal(np.asarray(agg_l[k]),
                                          np.asarray(agg_p[k]))

    @hp.given(st.integers(1, 6), st.sampled_from([8, 4]),
              st.sampled_from(["mean", "median", "trimmed_mean"]),
              st.integers(0, 2**20))
    @hp.settings(max_examples=8, deadline=None)
    def test_wire_agg_kernel_matches_ref_masked(self, C, bits, agg, seed):
        from repro.kernels.quant_pack import quant_pack_ref
        k = jax.random.fold_in(KEY, seed)
        xs = jax.random.normal(k, (C, 256, 128))
        pcs = [quant_pack_ref(xs[c], jnp.int32(c + seed % 53), bits=bits)
               for c in range(C)]
        packed = jnp.stack([p for p, _ in pcs])
        scales = jnp.stack([s for _, s in pcs])
        mask = jax.random.bernoulli(jax.random.fold_in(k, 1), 0.6,
                                    (C, 1)).astype(jnp.float32)
        w1 = jnp.ones((C, 1), jnp.float32)
        a_k = wire_agg_2d(packed, scales, mask, w1, bits=bits,
                          aggregator=agg, interpret=True)
        a_r = jax.jit(functools.partial(wire_agg_ref, bits=bits,
                                        aggregator=agg))(packed, scales,
                                                         mask, w1)
        np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_r))


class TestWireRoundRoute:
    def _run(self, cfg, aggregate_fn=None):
        C = 6
        gp = {"w": jax.random.normal(KEY, (90, 11)),
              "b": jax.random.normal(jax.random.fold_in(KEY, 1), (13,))}
        delta = jax.tree.map(
            lambda x: 0.1 * jax.random.normal(jax.random.fold_in(KEY, 2),
                                              (C,) + x.shape), gp)
        residual = jax.tree.map(
            lambda x: jnp.zeros((C,) + x.shape, jnp.float32), gp)
        kw = {} if aggregate_fn is None else {"aggregate_fn": aggregate_fn}
        run = jax.jit(functools.partial(rounds.wire_round, cfg,
                                        num_workers=C, **kw))
        qkey, wkey = jax.random.split(jax.random.fold_in(KEY, 3))
        return run(delta=delta, theta=jnp.linspace(0.1, 1.0, C),
                   mask=jnp.array([1., 1., 0., 1., 1., 1.]),
                   global_params=gp, residual=residual,
                   ps_residual=compress.init_residual(gp),
                   qkey=qkey, wkey=wkey)

    @pytest.mark.parametrize("comp,agg", [("int8", "mean"),
                                          ("int8", "median"),
                                          ("int4", "trimmed_mean")])
    def test_packed_route_bit_identical_to_legacy(self, comp, agg):
        cfg = CommConfig(compressor=comp, channel="erasure", drop_prob=0.3,
                         aggregator=agg)
        out = self._run(cfg)  # defaults -> packed route engages
        # wrapping the default aggregate_fn defeats the `is` gate ->
        # the identical config runs the legacy dense route
        leg = self._run(cfg, aggregate_fn=lambda *a, **k:
                        channel.receive(*a, **k))
        for k in ("w", "b"):
            np.testing.assert_array_equal(np.asarray(out.global_params[k]),
                                          np.asarray(leg.global_params[k]))
            # EF residual: equal up to XLA FMA contraction of the final
            # subtract (routes subtract at different shapes)
            np.testing.assert_allclose(np.asarray(out.residual[k]),
                                       np.asarray(leg.residual[k]),
                                       rtol=0, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(out.mask_eff),
                                      np.asarray(leg.mask_eff))
        assert float(out.record.bytes_up) == float(leg.record.bytes_up)

    def test_gate(self):
        tree = {"w": jnp.zeros((4, 3), jnp.float32)}
        ok = CommConfig(compressor="int8", channel="erasure")
        assert compress.packed_wire_eligible(ok, tree)
        assert compress.packed_wire_eligible(
            CommConfig(compressor="int4"), tree)
        for bad in (CommConfig(),                                # identity
                    CommConfig(compressor="topk"),
                    CommConfig(compressor="int8", channel="awgn"),
                    CommConfig(compressor="int8", channel="composite"),
                    CommConfig(compressor="int8", adaptive_bits=True)):
            assert not compress.packed_wire_eligible(bad, tree)
        # mixed precision keeps the dense route's astype semantics
        assert not compress.packed_wire_eligible(
            ok, {"w": jnp.zeros((4, 3), jnp.bfloat16)})

    def test_golden_configs_stay_on_legacy_route(self):
        """Structural safety for tests/test_rounds.py pins: none of the
        golden-pinned configs qualifies for the packed route."""
        tree = {"w": jnp.zeros((4, 3), jnp.float32)}
        goldens = [CommConfig(),                                 # A/B/F
                   CommConfig(channel="erasure", drop_prob=0.35),   # ERA
                   CommConfig(channel="awgn", snr_db=10.0),         # AWGN
                   CommConfig(compressor="int8", adaptive_bits=True,
                              error_feedback=True)]                 # ADA
        assert not any(compress.packed_wire_eligible(g, tree)
                       for g in goldens)


class TestTreeAggregate:
    """Two-stage tree mean for fleets past the kernel's VMEM worker cap
    (ops.MEAN_WORKER_CAP): per-chunk masked weighted partial sums, one
    fleet-wide divide."""

    def _fleet(self, C, seed=0, rows=256):
        from repro.kernels.quant_pack import quant_pack_ref
        k = jax.random.fold_in(KEY, seed)
        xs = jax.random.normal(k, (C, rows, 128))
        pcs = [quant_pack_ref(xs[c], jnp.int32(c), bits=8) for c in range(C)]
        packed = jnp.stack([p for p, _ in pcs])
        scales = jnp.stack([s for _, s in pcs])
        mask = jax.random.bernoulli(jax.random.fold_in(k, 1), 0.7,
                                    (C,)).astype(jnp.float32)
        return packed, scales, mask, (rows, 128)

    def test_chunked_matches_flat_mean(self):
        """C=96 > cap routes through the tree; the result matches the
        flat single-stage mean up to f32 re-association."""
        packed, scales, mask, shape = self._fleet(96)
        out = wire_aggregate(packed, scales, mask, shape=shape,
                             interpret=True)
        C = packed.shape[0]
        flat = wire_agg_ref(packed, scales, mask.reshape(C, 1),
                            jnp.ones((C, 1), jnp.float32))
        np.testing.assert_allclose(np.asarray(out), np.asarray(flat),
                                   rtol=1e-5, atol=1e-5)

    def test_chunk_sum_kernel_matches_ref_bitwise(self):
        """The per-chunk 'sum' partial is bit-identical between the
        pallas kernel (interpret) and the jnp ref — the invariant that
        keeps kernel-vs-ref bitwise at every C under the tree."""
        packed, scales, mask, _ = self._fleet(96, seed=1)
        C = packed.shape[0]
        m2 = mask.reshape(C, 1)
        w2 = jnp.ones((C, 1), jnp.float32)
        from repro.kernels.wire_agg.ops import MEAN_WORKER_CAP as CAP
        for g0 in range(0, C, CAP):
            sl = slice(g0, g0 + CAP)
            a_k = wire_agg_2d(packed[sl], scales[sl], m2[sl], w2[sl],
                              aggregator="sum", interpret=True)
            a_r = wire_agg_ref(packed[sl], scales[sl], m2[sl], w2[sl],
                               aggregator="sum")
            np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_r))

    def test_small_fleet_single_stage_bitwise(self):
        """C <= cap keeps the legacy single-stage call bit-identical to
        the flat ref — existing pins never see the tree."""
        packed, scales, mask, shape = self._fleet(8, seed=2)
        out = wire_aggregate(packed, scales, mask, shape=shape,
                             interpret=True)
        flat = wire_agg_ref(packed, scales, mask.reshape(8, 1),
                            jnp.ones((8, 1), jnp.float32))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(flat))

    def test_forced_cap_uneven_chunks_bitwise(self):
        """worker_cap=3 on C=8 (uneven tail chunk) reproduces the manual
        two-stage computation bit-for-bit."""
        packed, scales, mask, shape = self._fleet(8, seed=3)
        out = wire_aggregate(packed, scales, mask, shape=shape,
                             interpret=True, worker_cap=3)
        m2 = mask.reshape(8, 1)
        w2 = jnp.ones((8, 1), jnp.float32)
        parts = [wire_agg_ref(packed[g:g + 3], scales[g:g + 3],
                              m2[g:g + 3], w2[g:g + 3], aggregator="sum")
                 for g in range(0, 8, 3)]
        man = sum(parts[1:], parts[0]) / jnp.maximum((m2 * w2).sum(), 1.0)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(man))

    def test_threshold_boundary(self):
        """C == cap stays single-stage (no chunks reported); C == cap+1
        trees into two chunks."""
        from repro.kernels.wire_agg import ops as wire_ops
        seen = []
        orig = runtime.note_dispatch
        try:
            runtime.note_dispatch = lambda n, i, **kw: seen.append(kw)
            for C in (4, 5):
                packed, scales, mask, shape = self._fleet(C, seed=4)
                wire_aggregate(packed, scales, mask, shape=shape,
                               interpret=True, worker_cap=4)
        finally:
            runtime.note_dispatch = orig
        assert "chunks" not in seen[0] and seen[0]["workers"] == 4, seen
        assert seen[1].get("chunks") == 2 and seen[1]["workers"] == 5, seen
        assert wire_ops.MEAN_WORKER_CAP == 64
